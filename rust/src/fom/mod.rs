//! First-order methods (§4 of the paper).
//!
//! These produce *low-accuracy* solutions fast. They are the building
//! blocks of the engine's initialization layer
//! (`crate::engine::init::Initializer`), which turns them into seed
//! working sets for every workload; every gradient here rides the same
//! chunked parallel kernels as cutting-plane pricing
//! (`crate::backend::{par_xtv, par_col_dots}`):
//!
//! * [`smoothing`] — Nesterov-smoothed hinge loss `F^τ` (value + gradient);
//! * [`prox`] — thresholding operators for the three regularizers
//!   (soft-thresholding; L∞ via the Moreau identity and an L1-ball
//!   projection; Slope via PAVA isotonic regression);
//! * [`fista`] — accelerated proximal gradient on the composite smoothed
//!   problem (§4.3);
//! * [`block_cd`] — cyclical proximal block coordinate descent for the
//!   Group-SVM regularizer (§4.3);
//! * [`screening`] — correlation screening (§4.4.1);
//! * [`subsample`] — subsample-and-average heuristics for large n
//!   (§4.4.2–4.4.3), parallelized with `std::thread`;
//! * [`objective`] — exact (non-smoothed) objective evaluators used for
//!   the ARA metric in the experiment harness.

pub mod block_cd;
pub mod fista;
pub mod objective;
pub mod prox;
pub mod screening;
pub mod smoothing;
pub mod subsample;

pub use fista::{fista, FistaParams, FistaResult, Penalty};
pub use smoothing::SmoothedHinge;
