//! Accelerated proximal gradient (FISTA) on the smoothed composite
//! problem `min F^τ(β, β₀) + Ω(β)` (§4.3).
//!
//! The momentum sequence is Beck–Teboulle's `q_{T+1} = (1+√(1+4q_T²))/2`;
//! the step size is `1/L` with `L = σ_max(X̃ᵀX̃)/(4τ)` estimated by power
//! iteration. The intercept β₀ is unpenalized (plain gradient step).

use crate::backend::{sigma_max_sq, Backend};
use crate::fom::prox::{prox_linf, prox_slope, soft_threshold};
use crate::fom::smoothing::{HingeWorkspace, SmoothedHinge};

/// Which regularizer Ω to use.
#[derive(Clone, Debug)]
pub enum Penalty {
    /// `λ‖β‖₁`
    L1(f64),
    /// `λ Σ_g ‖β_g‖∞` over the given groups
    GroupLinf { lambda: f64, groups: Vec<Vec<usize>> },
    /// Slope with sorted nonincreasing weights
    Slope(Vec<f64>),
}

impl Penalty {
    /// Apply the prox of `(1/L)·Ω` in place.
    pub fn prox(&self, beta: &mut Vec<f64>, inv_l: f64) {
        match self {
            Penalty::L1(lambda) => soft_threshold(beta, lambda * inv_l),
            Penalty::GroupLinf { lambda, groups } => {
                for g in groups {
                    let sub: Vec<f64> = g.iter().map(|&j| beta[j]).collect();
                    let prox = prox_linf(&sub, lambda * inv_l);
                    for (k, &j) in g.iter().enumerate() {
                        beta[j] = prox[k];
                    }
                }
            }
            Penalty::Slope(lams) => {
                *beta = prox_slope(beta, lams, inv_l);
            }
        }
    }

    /// Evaluate Ω(β).
    pub fn value(&self, beta: &[f64]) -> f64 {
        match self {
            Penalty::L1(lambda) => lambda * beta.iter().map(|v| v.abs()).sum::<f64>(),
            Penalty::GroupLinf { lambda, groups } => {
                lambda
                    * groups
                        .iter()
                        .map(|g| g.iter().fold(0.0f64, |m, &j| m.max(beta[j].abs())))
                        .sum::<f64>()
            }
            Penalty::Slope(lams) => crate::fom::objective::slope_norm(beta, lams),
        }
    }
}

/// FISTA hyperparameters.
#[derive(Clone, Debug)]
pub struct FistaParams {
    /// Smoothing parameter τ (paper: 0.2).
    pub tau: f64,
    /// Stop when `‖α_{T+1} − α_T‖ ≤ eta` (paper: 1e-3).
    pub eta: f64,
    /// Max iterations (paper: a couple hundred).
    pub max_iters: usize,
    /// Power-iteration steps for the Lipschitz estimate.
    pub power_iters: usize,
    /// Worker threads for the `Xᵀv` half of each gradient (1 = serial).
    /// Rides the same chunked [`crate::backend::par_xtv`] kernel as
    /// cutting-plane pricing, so results are bit-identical for any
    /// thread count.
    pub threads: usize,
    /// Fit the unpenalized intercept β₀ (default). Disabled for models
    /// without one — e.g. the RankSVM pairwise-difference view, where a
    /// free intercept would absorb every pair margin and the FOM would
    /// learn nothing.
    pub fit_intercept: bool,
}

impl Default for FistaParams {
    fn default() -> Self {
        Self {
            tau: 0.2,
            eta: 1e-3,
            max_iters: 200,
            power_iters: 30,
            threads: 1,
            fit_intercept: true,
        }
    }
}

/// FISTA output.
#[derive(Clone, Debug)]
pub struct FistaResult {
    /// Final coefficients.
    pub beta: Vec<f64>,
    /// Final intercept.
    pub beta0: f64,
    /// Iterations performed.
    pub iters: usize,
    /// Final smoothed composite objective.
    pub objective: f64,
}

/// Run FISTA on `min F^τ + Ω` from a (possibly zero) starting point.
pub fn fista(
    backend: &dyn Backend,
    y: &[f64],
    penalty: &Penalty,
    params: &FistaParams,
    beta_init: Option<(&[f64], f64)>,
) -> FistaResult {
    let n = backend.rows();
    let p = backend.cols();
    let sh = SmoothedHinge { tau: params.tau };
    // Lipschitz constant of ∇F^τ (×1.05 safety margin).
    let l = (sigma_max_sq(backend, params.power_iters) / (4.0 * params.tau)).max(1e-12) * 1.05;
    let inv_l = 1.0 / l;

    let (mut beta, mut beta0) = match beta_init {
        Some((b, b0)) => (b.to_vec(), b0),
        None => (vec![0.0; p], 0.0),
    };
    // momentum state
    let mut beta_prev = beta.clone();
    let mut beta0_prev = beta0;
    let mut q = 1.0f64;
    let mut ws = HingeWorkspace::new(n);
    let mut grad = vec![0.0; p];
    let mut iters = 0;

    for t in 0..params.max_iters {
        iters = t + 1;
        // extrapolated point α = β_t + ((q_t − 1)/q_{t+1})(β_t − β_{t−1})
        let q_next = 0.5 * (1.0 + (1.0 + 4.0 * q * q).sqrt());
        let mom = (q - 1.0) / q_next;
        let mut alpha: Vec<f64> = beta
            .iter()
            .zip(&beta_prev)
            .map(|(b, bp)| b + mom * (b - bp))
            .collect();
        let alpha0 = beta0 + mom * (beta0 - beta0_prev);
        q = q_next;

        let (_f, g0) =
            sh.value_grad_mt(backend, y, &alpha, alpha0, &mut ws, &mut grad, params.threads);
        // gradient step then prox
        for (a, g) in alpha.iter_mut().zip(&grad) {
            *a -= inv_l * g;
        }
        let new_beta0 = if params.fit_intercept { alpha0 - inv_l * g0 } else { 0.0 };
        penalty.prox(&mut alpha, inv_l);

        // convergence: ‖(β,β₀) change‖
        let mut delta = (new_beta0 - beta0).powi(2);
        for (a, b) in alpha.iter().zip(&beta) {
            delta += (a - b) * (a - b);
        }
        beta_prev = std::mem::replace(&mut beta, alpha);
        beta0_prev = beta0;
        beta0 = new_beta0;
        if delta.sqrt() <= params.eta {
            break;
        }
    }
    let obj = sh.value(backend, y, &beta, beta0, &mut ws) + penalty.value(&beta);
    FistaResult { beta, beta0, iters, objective: obj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::fom::objective::l1_objective;
    use crate::rng::Xoshiro256;

    #[test]
    fn fista_decreases_objective_and_sparsifies() {
        let mut rng = Xoshiro256::seed_from_u64(51);
        let spec = SyntheticSpec { n: 60, p: 120, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.3 * ds.lambda_max_l1();
        let params = FistaParams { max_iters: 400, eta: 1e-6, ..Default::default() };
        let res = fista(&backend, &ds.y, &Penalty::L1(lambda), &params, None);

        let obj_zero = l1_objective(&backend, &ds.y, &vec![0.0; ds.p()], 0.0, lambda);
        let obj = l1_objective(&backend, &ds.y, &res.beta, res.beta0, lambda);
        assert!(obj < obj_zero, "fista did not improve: {obj} vs {obj_zero}");
        // strong regularization → sparse-ish solution
        let nnz = res.beta.iter().filter(|v| v.abs() > 1e-6).count();
        assert!(nnz < ds.p() / 2, "nnz {nnz}");
    }

    #[test]
    fn fista_near_stationary_point_for_l1() {
        // At convergence the prox fixed-point residual should be small.
        let mut rng = Xoshiro256::seed_from_u64(52);
        let spec = SyntheticSpec { n: 40, p: 30, k0: 5, rho: 0.0, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.1 * ds.lambda_max_l1();
        let params = FistaParams { max_iters: 3000, eta: 1e-10, ..Default::default() };
        let res = fista(&backend, &ds.y, &Penalty::L1(lambda), &params, None);

        // check the subgradient condition of the SMOOTHED problem:
        // for β_j ≠ 0: |∇F_j + λ sign(β_j)| small; for β_j = 0: |∇F_j| ≤ λ+tol
        let sh = SmoothedHinge { tau: params.tau };
        let mut ws = HingeWorkspace::new(ds.n());
        let mut grad = vec![0.0; ds.p()];
        let (_f, g0) =
            sh.value_grad(&backend, &ds.y, &res.beta, res.beta0, &mut ws, &mut grad);
        assert!(g0.abs() < 1e-3, "intercept gradient {g0}");
        for j in 0..ds.p() {
            if res.beta[j].abs() > 1e-6 {
                let r = grad[j] + lambda * res.beta[j].signum();
                assert!(r.abs() < 1e-2, "j={j} stationarity {r}");
            } else {
                assert!(grad[j].abs() <= lambda + 1e-2, "j={j} |g|={} λ={lambda}", grad[j]);
            }
        }
    }

    #[test]
    fn fista_group_and_slope_penalties_run() {
        let mut rng = Xoshiro256::seed_from_u64(53);
        let spec = SyntheticSpec { n: 30, p: 20, k0: 4, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let groups: Vec<Vec<usize>> = (0..5).map(|g| (g * 4..(g + 1) * 4).collect()).collect();
        let pg = Penalty::GroupLinf { lambda: 0.5, groups };
        let rg = fista(&backend, &ds.y, &pg, &FistaParams::default(), None);
        assert!(rg.objective.is_finite());

        let lams = crate::fom::objective::bh_slope_weights(20, 0.2);
        let ps = Penalty::Slope(lams);
        let rs = fista(&backend, &ds.y, &ps, &FistaParams::default(), None);
        assert!(rs.objective.is_finite());
    }

    #[test]
    fn fista_threads_are_bit_identical() {
        let mut rng = Xoshiro256::seed_from_u64(55);
        let spec = SyntheticSpec { n: 40, p: 90, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.1 * ds.lambda_max_l1();
        let serial = fista(
            &backend,
            &ds.y,
            &Penalty::L1(lambda),
            &FistaParams { max_iters: 150, threads: 1, ..Default::default() },
            None,
        );
        for t in [2usize, 4, 7] {
            let par = fista(
                &backend,
                &ds.y,
                &Penalty::L1(lambda),
                &FistaParams { max_iters: 150, threads: t, ..Default::default() },
                None,
            );
            assert_eq!(par.iters, serial.iters, "{t} threads");
            assert_eq!(par.beta0, serial.beta0, "{t} threads");
            assert_eq!(par.beta, serial.beta, "{t} threads");
        }
    }

    #[test]
    fn fista_without_intercept_keeps_beta0_zero() {
        let mut rng = Xoshiro256::seed_from_u64(56);
        let spec = SyntheticSpec { n: 30, p: 40, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.1 * ds.lambda_max_l1();
        let res = fista(
            &backend,
            &ds.y,
            &Penalty::L1(lambda),
            &FistaParams { fit_intercept: false, ..Default::default() },
            None,
        );
        assert_eq!(res.beta0, 0.0);
        assert!(res.beta.iter().any(|v| *v != 0.0), "coefficients must still move");
    }

    #[test]
    fn warm_start_converges_faster() {
        let mut rng = Xoshiro256::seed_from_u64(54);
        let spec = SyntheticSpec { n: 50, p: 60, k0: 5, rho: 0.1, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let backend = NativeBackend::new(&ds.x);
        let lambda = 0.2 * ds.lambda_max_l1();
        let p1 = FistaParams { max_iters: 500, eta: 1e-7, ..Default::default() };
        let cold = fista(&backend, &ds.y, &Penalty::L1(lambda), &p1, None);
        let warm = fista(
            &backend,
            &ds.y,
            &Penalty::L1(lambda),
            &p1,
            Some((&cold.beta, cold.beta0)),
        );
        assert!(warm.iters <= cold.iters, "warm {} cold {}", warm.iters, cold.iters);
    }
}
