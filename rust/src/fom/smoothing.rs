//! Nesterov smoothing of the hinge loss (§4.1).
//!
//! `F^τ(β, β₀) = max_{‖w‖∞≤1} Σ ½[z_i + w_i z_i] − (τ/2)‖w‖²` with
//! `z_i = 1 − y_i(x_iᵀβ + β₀)`; the maximizer is
//! `w_i^τ = clip(z_i / 2τ, −1, 1)` and
//!
//! * value: `Σ ½ z_i (1 + w_i^τ) − (τ/2)‖w^τ‖²`
//! * gradient: `∇_β F = −½ Xᵀ(y ∘ (1 + w^τ))`, `∇_{β₀} F = −½ Σ y_i(1+w_i^τ)`
//!
//! The two O(np) products run through a [`Backend`].

use crate::backend::{par_xtv, Backend};

/// Smoothed hinge loss with parameter τ.
#[derive(Clone, Copy, Debug)]
pub struct SmoothedHinge {
    /// Smoothing parameter τ > 0 (paper uses 0.2).
    pub tau: f64,
}

/// Work buffers reused across gradient evaluations (avoids allocating in
/// the FISTA loop).
pub struct HingeWorkspace {
    /// margins `z = 1 − y∘(Xβ + β₀)`
    pub z: Vec<f64>,
    /// smoothed dual weights `w^τ`
    pub w: Vec<f64>,
    /// scratch `y ∘ (1 + w)/2`
    pub v: Vec<f64>,
}

impl HingeWorkspace {
    /// Allocate for n samples.
    pub fn new(n: usize) -> Self {
        Self { z: vec![0.0; n], w: vec![0.0; n], v: vec![0.0; n] }
    }
}

impl SmoothedHinge {
    /// Evaluate value and gradient at `(β, β₀)` (serial `Xᵀv`).
    ///
    /// Returns `(F^τ, ∇β ∈ ℝᵖ written into grad_beta, ∇β₀)`.
    pub fn value_grad(
        &self,
        backend: &dyn Backend,
        y: &[f64],
        beta: &[f64],
        beta0: f64,
        ws: &mut HingeWorkspace,
        grad_beta: &mut [f64],
    ) -> (f64, f64) {
        self.value_grad_mt(backend, y, beta, beta0, ws, grad_beta, 1)
    }

    /// [`SmoothedHinge::value_grad`] with the `Xᵀv` half of the gradient
    /// chunked over `threads` workers — the same
    /// [`crate::backend::par_xtv`] kernel as cutting-plane pricing, so
    /// the result is bit-identical for any thread count.
    #[allow(clippy::too_many_arguments)]
    pub fn value_grad_mt(
        &self,
        backend: &dyn Backend,
        y: &[f64],
        beta: &[f64],
        beta0: f64,
        ws: &mut HingeWorkspace,
        grad_beta: &mut [f64],
        threads: usize,
    ) -> (f64, f64) {
        let n = backend.rows();
        debug_assert_eq!(y.len(), n);
        debug_assert_eq!(grad_beta.len(), backend.cols());
        // z = 1 − y∘(Xβ + β₀)
        backend.xb(beta, &mut ws.z);
        let tau = self.tau;
        let mut value = 0.0;
        let mut grad_b0 = 0.0;
        for i in 0..n {
            let z = 1.0 - y[i] * (ws.z[i] + beta0);
            ws.z[i] = z;
            let w = (z / (2.0 * tau)).clamp(-1.0, 1.0);
            ws.w[i] = w;
            value += 0.5 * z * (1.0 + w) - 0.5 * tau * w * w;
            let coeff = 0.5 * y[i] * (1.0 + w);
            ws.v[i] = coeff;
            grad_b0 -= coeff;
        }
        // ∇β = −Xᵀ v with v_i = y_i (1+w_i)/2
        par_xtv(backend, threads, &ws.v, grad_beta);
        for g in grad_beta.iter_mut() {
            *g = -*g;
        }
        (value, grad_b0)
    }

    /// Value only (cheaper bookkeeping, same matvec cost).
    pub fn value(
        &self,
        backend: &dyn Backend,
        y: &[f64],
        beta: &[f64],
        beta0: f64,
        ws: &mut HingeWorkspace,
    ) -> f64 {
        let n = backend.rows();
        backend.xb(beta, &mut ws.z);
        let tau = self.tau;
        let mut value = 0.0;
        for i in 0..n {
            let z = 1.0 - y[i] * (ws.z[i] + beta0);
            let w = (z / (2.0 * tau)).clamp(-1.0, 1.0);
            value += 0.5 * z * (1.0 + w) - 0.5 * tau * w * w;
        }
        value
    }

    /// Pointwise smoothed hinge of a scalar margin (test helper; equals
    /// `max(0, z)` up to O(τ)).
    pub fn scalar(&self, z: f64) -> f64 {
        let w = (z / (2.0 * self.tau)).clamp(-1.0, 1.0);
        0.5 * z * (1.0 + w) - 0.5 * self.tau * w * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::Design;
    use crate::linalg::Matrix;
    use crate::rng::Xoshiro256;

    #[test]
    fn scalar_smoothing_approximates_hinge() {
        let sh = SmoothedHinge { tau: 0.1 };
        // saturated regions: F = hinge − τ/2 exactly
        assert!((sh.scalar(3.0) - (3.0 - 0.05)).abs() < 1e-12);
        assert!((sh.scalar(-3.0) - (-0.05)).abs() < 1e-12);
        // Nesterov bound everywhere: hinge − τ/2 ≤ F ≤ hinge
        for z in [-0.3f64, -0.05, 0.0, 0.05, 0.3, 1.0, -1.0] {
            let hinge = z.max(0.0);
            let f = sh.scalar(z);
            assert!(f <= hinge + 1e-12, "z={z}");
            assert!(f >= hinge - 0.05 - 1e-12, "z={z}");
        }
        // at z = 0: w = 0 → F = 0
        assert!(sh.scalar(0.0).abs() < 1e-15);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Xoshiro256::seed_from_u64(41);
        let (n, p) = (15, 7);
        let mut m = Matrix::zeros(n, p);
        for i in 0..n {
            for j in 0..p {
                m.set(i, j, rng.normal());
            }
        }
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let d = Design::dense(m);
        let backend = NativeBackend::new(&d);
        let sh = SmoothedHinge { tau: 0.25 };
        let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 0.3).collect();
        let beta0 = 0.2;
        let mut ws = HingeWorkspace::new(n);
        let mut grad = vec![0.0; p];
        let (f0, g0) = sh.value_grad(&backend, &y, &beta, beta0, &mut ws, &mut grad);

        let h = 1e-6;
        for j in 0..p {
            let mut bp = beta.clone();
            bp[j] += h;
            let fp = sh.value(&backend, &y, &bp, beta0, &mut ws);
            let fd = (fp - f0) / h;
            assert!((fd - grad[j]).abs() < 1e-4, "j={j}: fd {fd} grad {}", grad[j]);
        }
        let fp = sh.value(&backend, &y, &beta, beta0 + h, &mut ws);
        let fd0 = (fp - f0) / h;
        assert!((fd0 - g0).abs() < 1e-4, "b0: fd {fd0} grad {g0}");
    }

    #[test]
    fn value_upper_bounds_do_not_exceed_hinge_plus_tau_bound() {
        // F^τ(z) ∈ [hinge(z) − τ/2·n?, hinge(z)] per-sample bound
        let mut rng = Xoshiro256::seed_from_u64(42);
        let sh = SmoothedHinge { tau: 0.2 };
        for _ in 0..200 {
            let z = rng.normal() * 2.0;
            let f = sh.scalar(z);
            let hinge = z.max(0.0);
            assert!(f <= hinge + 1e-12);
            assert!(f >= hinge - 0.1 - 1e-12); // τ/2 = 0.1
        }
    }
}
