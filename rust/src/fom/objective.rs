//! Exact (non-smoothed) objective evaluators.
//!
//! Used for the ARA (averaged relative accuracy) metric in the benchmark
//! harness and as the cross-method comparison yardstick: every algorithm
//! — cutting plane, full LP, PSM, ADMM, FOM — is scored by the true
//! objective of the problem it solves.

use crate::backend::Backend;

/// Hinge loss `Σ (1 − y_i(x_iᵀβ + β₀))₊`.
pub fn hinge_loss(backend: &dyn Backend, y: &[f64], beta: &[f64], beta0: f64) -> f64 {
    let n = backend.rows();
    let mut xb = vec![0.0; n];
    backend.xb(beta, &mut xb);
    let mut s = 0.0;
    for i in 0..n {
        s += (1.0 - y[i] * (xb[i] + beta0)).max(0.0);
    }
    s
}

/// Hinge loss when β is supported on a column subset (avoids densifying).
pub fn hinge_loss_support(
    design: &crate::data::Design,
    y: &[f64],
    cols: &[usize],
    beta: &[f64],
    beta0: f64,
) -> f64 {
    let n = design.rows();
    let mut xb = vec![0.0; n];
    design.matvec_cols(cols, beta, &mut xb);
    let mut s = 0.0;
    for i in 0..n {
        s += (1.0 - y[i] * (xb[i] + beta0)).max(0.0);
    }
    s
}

/// L1-SVM objective (Problem 2).
pub fn l1_objective(
    backend: &dyn Backend,
    y: &[f64],
    beta: &[f64],
    beta0: f64,
    lambda: f64,
) -> f64 {
    hinge_loss(backend, y, beta, beta0) + lambda * beta.iter().map(|v| v.abs()).sum::<f64>()
}

/// Group-SVM objective (Problem 3), `Ω = λ Σ_g ‖β_g‖∞`.
pub fn group_objective(
    backend: &dyn Backend,
    y: &[f64],
    beta: &[f64],
    beta0: f64,
    lambda: f64,
    groups: &[Vec<usize>],
) -> f64 {
    let pen: f64 = groups
        .iter()
        .map(|g| g.iter().fold(0.0f64, |m, &j| m.max(beta[j].abs())))
        .sum();
    hinge_loss(backend, y, beta, beta0) + lambda * pen
}

/// Slope norm `Σ_j λ_j |β|_(j)` for a sorted nonincreasing weight vector.
pub fn slope_norm(beta: &[f64], lambda: &[f64]) -> f64 {
    let mut a: Vec<f64> = beta.iter().map(|v| v.abs()).collect();
    a.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
    a.iter().zip(lambda).map(|(v, l)| v * l).sum()
}

/// Slope-SVM objective (Problem 4).
pub fn slope_objective(
    backend: &dyn Backend,
    y: &[f64],
    beta: &[f64],
    beta0: f64,
    lambda: &[f64],
) -> f64 {
    hinge_loss(backend, y, beta, beta0) + slope_norm(beta, lambda)
}

/// The Benjamini–Hochberg-style Slope weight sequence used in Table 6:
/// `λ_j = √(log(2p/j)) · λ̃`.
pub fn bh_slope_weights(p: usize, lambda_tilde: f64) -> Vec<f64> {
    (1..=p)
        .map(|j| (2.0 * p as f64 / j as f64).ln().sqrt() * lambda_tilde)
        .collect()
}

/// The two-level Slope weights of Table 5: `2λ̃` on the first `k0`,
/// `λ̃` after.
pub fn two_level_slope_weights(p: usize, k0: usize, lambda_tilde: f64) -> Vec<f64> {
    (0..p).map(|j| if j < k0 { 2.0 * lambda_tilde } else { lambda_tilde }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::Design;
    use crate::linalg::Matrix;

    fn tiny() -> (Design, Vec<f64>) {
        let m = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        (Design::dense(m), vec![1.0, -1.0])
    }

    #[test]
    fn hinge_and_l1_objective() {
        let (d, y) = tiny();
        let b = NativeBackend::new(&d);
        // β = (1, 1), β₀ = 0: margins y(xβ) = (1, -1) → hinge = 0 + 2
        let obj = l1_objective(&b, &y, &[1.0, 1.0], 0.0, 0.5);
        assert!((obj - (2.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn hinge_support_matches_dense() {
        let (d, y) = tiny();
        let b = NativeBackend::new(&d);
        let full = hinge_loss(&b, &y, &[0.0, 2.0], 0.1);
        let sup = hinge_loss_support(&d, &y, &[1], &[2.0], 0.1);
        assert!((full - sup).abs() < 1e-12);
    }

    #[test]
    fn group_objective_uses_linf() {
        let (d, y) = tiny();
        let b = NativeBackend::new(&d);
        let groups = vec![vec![0, 1]];
        let obj = group_objective(&b, &y, &[1.0, -3.0], 0.0, 2.0, &groups);
        let hinge = hinge_loss(&b, &y, &[1.0, -3.0], 0.0);
        assert!((obj - (hinge + 2.0 * 3.0)).abs() < 1e-12);
    }

    #[test]
    fn slope_norm_sorts() {
        let lam = vec![2.0, 1.0];
        assert!((slope_norm(&[1.0, -3.0], &lam) - (2.0 * 3.0 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn weight_sequences() {
        let w = bh_slope_weights(4, 1.0);
        assert!(w.windows(2).all(|x| x[0] >= x[1]));
        assert!((w[0] - (8.0f64).ln().sqrt()).abs() < 1e-12);
        let t = two_level_slope_weights(5, 2, 0.5);
        assert_eq!(t, vec![1.0, 1.0, 0.5, 0.5, 0.5]);
    }
}
