//! Correlation screening (§4.4.1).
//!
//! For standardized features, `|x_jᵀ y|` ranks features by marginal
//! association with the labels; the paper keeps the top `10n` features
//! (or top `n` groups) before running a first-order method, and uses the
//! top `~n` features directly as a column-generation initializer.

use crate::backend::{par_xtv, Backend, NativeBackend};
use crate::data::Design;

/// Indices of the `k` features with the largest `|x_jᵀ y|`, sorted by
/// decreasing score. Thin wrapper over [`correlation_screen_backend`]
/// with the native kernels and serial scoring (the call sites inside
/// subsample workers must not nest thread pools).
pub fn correlation_screen(design: &Design, y: &[f64], k: usize) -> Vec<usize> {
    correlation_screen_backend(&NativeBackend::new(design), y, k, 1)
}

/// [`correlation_screen`] on an arbitrary [`Backend`], with the score
/// matvec `Xᵀy` running through the shared chunked [`par_xtv`] kernel —
/// sparse designs score at O(nnz) and the ranking is bit-identical at
/// any thread count.
pub fn correlation_screen_backend(
    backend: &dyn Backend,
    y: &[f64],
    k: usize,
    threads: usize,
) -> Vec<usize> {
    let p = backend.cols();
    let mut scores = vec![0.0; p];
    par_xtv(backend, threads, y, &mut scores);
    top_k_by_abs(&scores, k.min(p))
}

/// Indices of the `k` groups with the largest `Σ_{j∈g} |x_jᵀ y|`.
pub fn group_screen(design: &Design, y: &[f64], groups: &[Vec<usize>], k: usize) -> Vec<usize> {
    group_screen_backend(&NativeBackend::new(design), y, groups, k, 1)
}

/// [`group_screen`] on an arbitrary [`Backend`]; see
/// [`correlation_screen_backend`].
pub fn group_screen_backend(
    backend: &dyn Backend,
    y: &[f64],
    groups: &[Vec<usize>],
    k: usize,
    threads: usize,
) -> Vec<usize> {
    let p = backend.cols();
    let mut scores = vec![0.0; p];
    par_xtv(backend, threads, y, &mut scores);
    let gscores: Vec<f64> = groups
        .iter()
        .map(|g| g.iter().map(|&j| scores[j].abs()).sum())
        .collect();
    let mut idx: Vec<usize> = (0..groups.len()).collect();
    idx.sort_unstable_by(|&a, &b| gscores[b].partial_cmp(&gscores[a]).unwrap());
    idx.truncate(k.min(groups.len()));
    idx
}

/// Indices of the `k` largest entries of `scores` by absolute value,
/// ordered by decreasing |score| (deterministic tie-break by index).
pub fn top_k_by_abs(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        scores[b]
            .abs()
            .partial_cmp(&scores[a].abs())
            .unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn top_k_orders_by_abs() {
        let got = top_k_by_abs(&[0.1, -5.0, 3.0, -0.2], 3);
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn screening_finds_informative_features() {
        let mut rng = Xoshiro256::seed_from_u64(71);
        let spec = SyntheticSpec { n: 150, p: 300, k0: 8, rho: 0.05, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let picked = correlation_screen(&ds.x, &ds.y, 20);
        let hits = picked.iter().filter(|&&j| j < 8).count();
        assert!(hits >= 7, "screening found only {hits}/8 informative features");
    }

    #[test]
    fn backend_screening_is_thread_invariant() {
        use crate::data::synthetic::{generate_sparse_text, SparseTextSpec};
        // par_xtv is bit-identical at any thread count, so the ranking —
        // ties broken by index — cannot move either
        let spec = SparseTextSpec { n: 400, p: 1500, density: 0.02, k0: 10, zipf: 1.1 };
        let ds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(73));
        let base = correlation_screen(&ds.x, &ds.y, 50);
        let backend = crate::backend::NativeBackend::new(&ds.x);
        for t in [1usize, 2, 4] {
            assert_eq!(
                correlation_screen_backend(&backend, &ds.y, 50, t),
                base,
                "screening ranking moved at {t} threads"
            );
        }
    }

    #[test]
    fn group_screening_finds_informative_groups() {
        use crate::data::synthetic::{generate_group, GroupSpec};
        let mut rng = Xoshiro256::seed_from_u64(72);
        let spec = GroupSpec {
            n: 100,
            n_groups: 30,
            group_size: 5,
            k0_groups: 4,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut rng);
        let picked = group_screen(&gd.data.x, &gd.data.y, &gd.groups, 8);
        let hits = picked.iter().filter(|&&g| g < 4).count();
        assert!(hits >= 3, "group screening found only {hits}/4");
    }
}
