//! Correlation screening (§4.4.1).
//!
//! For standardized features, `|x_jᵀ y|` ranks features by marginal
//! association with the labels; the paper keeps the top `10n` features
//! (or top `n` groups) before running a first-order method, and uses the
//! top `~n` features directly as a column-generation initializer.

use crate::data::Design;

/// Indices of the `k` features with the largest `|x_jᵀ y|`, sorted by
/// decreasing score.
pub fn correlation_screen(design: &Design, y: &[f64], k: usize) -> Vec<usize> {
    let p = design.cols();
    let mut scores = vec![0.0; p];
    design.tmatvec(y, &mut scores);
    top_k_by_abs(&scores, k.min(p))
}

/// Indices of the `k` groups with the largest `Σ_{j∈g} |x_jᵀ y|`.
pub fn group_screen(design: &Design, y: &[f64], groups: &[Vec<usize>], k: usize) -> Vec<usize> {
    let p = design.cols();
    let mut scores = vec![0.0; p];
    design.tmatvec(y, &mut scores);
    let gscores: Vec<f64> = groups
        .iter()
        .map(|g| g.iter().map(|&j| scores[j].abs()).sum())
        .collect();
    let mut idx: Vec<usize> = (0..groups.len()).collect();
    idx.sort_unstable_by(|&a, &b| gscores[b].partial_cmp(&gscores[a]).unwrap());
    idx.truncate(k.min(groups.len()));
    idx
}

/// Indices of the `k` largest entries of `scores` by absolute value,
/// ordered by decreasing |score| (deterministic tie-break by index).
pub fn top_k_by_abs(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        scores[b]
            .abs()
            .partial_cmp(&scores[a].abs())
            .unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};
    use crate::rng::Xoshiro256;

    #[test]
    fn top_k_orders_by_abs() {
        let got = top_k_by_abs(&[0.1, -5.0, 3.0, -0.2], 3);
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn screening_finds_informative_features() {
        let mut rng = Xoshiro256::seed_from_u64(71);
        let spec = SyntheticSpec { n: 150, p: 300, k0: 8, rho: 0.05, standardize: true };
        let ds = generate_l1(&spec, &mut rng);
        let picked = correlation_screen(&ds.x, &ds.y, 20);
        let hits = picked.iter().filter(|&&j| j < 8).count();
        assert!(hits >= 7, "screening found only {hits}/8 informative features");
    }

    #[test]
    fn group_screening_finds_informative_groups() {
        use crate::data::synthetic::{generate_group, GroupSpec};
        let mut rng = Xoshiro256::seed_from_u64(72);
        let spec = GroupSpec {
            n: 100,
            n_groups: 30,
            group_size: 5,
            k0_groups: 4,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut rng);
        let picked = group_screen(&gd.data.x, &gd.data.y, &gd.groups, 8);
        let hits = picked.iter().filter(|&&g| g < 4).count();
        assert!(hits >= 3, "group screening found only {hits}/4");
    }
}
