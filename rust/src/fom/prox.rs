//! Thresholding (proximal) operators for the three regularizers (§4.2).
//!
//! Each computes `argmin_β ½‖β − η‖² + μ·Ω(β)`:
//!
//! * `Ω = ‖·‖₁` — componentwise soft-thresholding;
//! * `Ω = ‖·‖∞` — via the Moreau identity `prox_{μ‖·‖∞}(η) = η −
//!   Π_{μ·B₁}(η)` with `Π` the Euclidean projection onto the L1 ball
//!   (computed by the sort-based method of Duchi et al. / van den Berg &
//!   Friedlander);
//! * `Ω = Slope` — reduces to an isotonic-regression-like problem on the
//!   sorted absolute values, solved exactly by PAVA (§4.2, eq. 46).

/// Scalar soft-threshold: `sign(c)·(|c| − μ)₊`.
#[inline]
pub fn soft_threshold_scalar(c: f64, mu: f64) -> f64 {
    if c > mu {
        c - mu
    } else if c < -mu {
        c + mu
    } else {
        0.0
    }
}

/// Componentwise soft-thresholding (prox of `μ‖·‖₁`), in place.
pub fn soft_threshold(eta: &mut [f64], mu: f64) {
    for v in eta.iter_mut() {
        *v = soft_threshold_scalar(*v, mu);
    }
}

/// Euclidean projection of `eta` onto the L1 ball of radius `radius`.
///
/// Sort-based exact algorithm: find the soft-threshold level θ such that
/// `Σ (|η_i| − θ)₊ = radius` (zero if `‖η‖₁ ≤ radius`).
pub fn project_l1_ball(eta: &[f64], radius: f64) -> Vec<f64> {
    assert!(radius >= 0.0);
    let l1: f64 = eta.iter().map(|v| v.abs()).sum();
    if l1 <= radius {
        return eta.to_vec();
    }
    if radius == 0.0 {
        return vec![0.0; eta.len()];
    }
    let mut abs: Vec<f64> = eta.iter().map(|v| v.abs()).collect();
    abs.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cum = 0.0;
    let mut theta = 0.0;
    for (k, &a) in abs.iter().enumerate() {
        cum += a;
        let t = (cum - radius) / (k as f64 + 1.0);
        if k + 1 == abs.len() || t >= abs[k + 1] {
            theta = t;
            break;
        }
    }
    eta.iter().map(|&v| soft_threshold_scalar(v, theta)).collect()
}

/// Prox of `μ‖·‖∞` via the Moreau decomposition (eq. 44).
pub fn prox_linf(eta: &[f64], mu: f64) -> Vec<f64> {
    let proj = project_l1_ball(eta, mu);
    eta.iter().zip(&proj).map(|(e, p)| e - p).collect()
}

/// Prox of the Slope norm `Σ λ_j |β|_(j)` scaled by `mu`
/// (i.e. weights `μ·λ_j`), for a *sorted nonincreasing nonnegative*
/// weight vector `lambda`.
///
/// Algorithm (Bogdan et al. 2015, eq. 45–46): take the decreasing
/// rearrangement of |η|, subtract the weights, then project onto the
/// isotonic cone `u₁ ≥ … ≥ u_p ≥ 0` via PAVA; finally undo sorting and
/// restore signs.
pub fn prox_slope(eta: &[f64], lambda: &[f64], mu: f64) -> Vec<f64> {
    let p = eta.len();
    assert_eq!(lambda.len(), p);
    debug_assert!(lambda.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    // order[k] = index of the k-th largest |η|
    let mut order: Vec<usize> = (0..p).collect();
    order.sort_unstable_by(|&a, &b| eta[b].abs().partial_cmp(&eta[a].abs()).unwrap());
    // PAVA on z_k = |η|_(k) − μ λ_k for the nonincreasing constraint.
    let z: Vec<f64> = order
        .iter()
        .zip(lambda)
        .map(|(&idx, &l)| eta[idx].abs() - mu * l)
        .collect();
    let u = pava_nonincreasing(&z);
    let mut out = vec![0.0; p];
    for (k, &idx) in order.iter().enumerate() {
        out[idx] = eta[idx].signum() * u[k].max(0.0);
    }
    out
}

/// Pool-adjacent-violators for `min ½‖u − z‖²` s.t. `u₁ ≥ u₂ ≥ … ≥ u_p`
/// (no positivity — callers clamp afterwards, which is exact for this
/// composite because the objective separates at zero).
pub fn pava_nonincreasing(z: &[f64]) -> Vec<f64> {
    // Classic stack of blocks with (sum, count).
    let mut sums: Vec<f64> = Vec::with_capacity(z.len());
    let mut counts: Vec<usize> = Vec::with_capacity(z.len());
    for &v in z {
        let mut s = v;
        let mut c = 1usize;
        // merging while previous block mean is SMALLER than current mean
        // (violates nonincreasing)
        while let (Some(&ps), Some(&pc)) = (sums.last(), counts.last()) {
            if ps / (pc as f64) < s / (c as f64) {
                s += ps;
                c += pc;
                sums.pop();
                counts.pop();
            } else {
                break;
            }
        }
        sums.push(s);
        counts.push(c);
    }
    let mut out = Vec::with_capacity(z.len());
    for (s, c) in sums.iter().zip(&counts) {
        let mean = s / *c as f64;
        out.extend(std::iter::repeat(mean).take(*c));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn slope_norm(beta: &[f64], lambda: &[f64], mu: f64) -> f64 {
        let mut a: Vec<f64> = beta.iter().map(|v| v.abs()).collect();
        a.sort_unstable_by(|x, y| y.partial_cmp(x).unwrap());
        a.iter().zip(lambda).map(|(v, l)| mu * l * v).sum()
    }

    fn slope_prox_objective(beta: &[f64], eta: &[f64], lambda: &[f64], mu: f64) -> f64 {
        let quad: f64 = beta.iter().zip(eta).map(|(b, e)| 0.5 * (b - e) * (b - e)).sum();
        quad + slope_norm(beta, lambda, mu)
    }

    #[test]
    fn soft_threshold_basics() {
        assert_eq!(soft_threshold_scalar(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold_scalar(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold_scalar(0.5, 1.0), 0.0);
        let mut v = vec![2.0, -0.5, -4.0];
        soft_threshold(&mut v, 1.0);
        assert_eq!(v, vec![1.0, 0.0, -3.0]);
    }

    #[test]
    fn l1_projection_inside_ball_is_identity() {
        let eta = [0.2, -0.3, 0.1];
        assert_eq!(project_l1_ball(&eta, 1.0), eta.to_vec());
    }

    #[test]
    fn l1_projection_known_case() {
        // Project (3, 1) onto L1 ball radius 2: θ solves (3−θ)+(1−θ)=2 if
        // both positive → θ=1 → (2, 0).
        let p = project_l1_ball(&[3.0, 1.0], 2.0);
        assert!((p[0] - 2.0).abs() < 1e-12);
        assert!(p[1].abs() < 1e-12);
    }

    #[test]
    fn l1_projection_properties_random() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        for _ in 0..100 {
            let p = 1 + rng.below(20);
            let eta: Vec<f64> = (0..p).map(|_| rng.normal() * 3.0).collect();
            let r = rng.uniform() * 4.0;
            let proj = project_l1_ball(&eta, r);
            let l1: f64 = proj.iter().map(|v| v.abs()).sum();
            assert!(l1 <= r + 1e-9, "outside ball: {l1} > {r}");
            // projection optimality: for any feasible candidate (scaled
            // eta), distance must not be smaller
            let eta_l1: f64 = eta.iter().map(|v| v.abs()).sum();
            if eta_l1 > 0.0 {
                let cand: Vec<f64> = eta.iter().map(|v| v * (r / eta_l1).min(1.0)).collect();
                let d_proj: f64 = proj.iter().zip(&eta).map(|(a, b)| (a - b) * (a - b)).sum();
                let d_cand: f64 = cand.iter().zip(&eta).map(|(a, b)| (a - b) * (a - b)).sum();
                assert!(d_proj <= d_cand + 1e-9);
            }
        }
    }

    #[test]
    fn moreau_identity_holds() {
        let mut rng = Xoshiro256::seed_from_u64(32);
        for _ in 0..50 {
            let p = 1 + rng.below(12);
            let eta: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
            let mu = 0.1 + rng.uniform() * 2.0;
            let prox = prox_linf(&eta, mu);
            let proj = project_l1_ball(&eta, mu);
            for k in 0..p {
                assert!((prox[k] + proj[k] - eta[k]).abs() < 1e-12);
            }
            // prox result must satisfy: max |prox| appears where it should;
            // verify optimality by random perturbations
            let obj = |b: &[f64]| -> f64 {
                let quad: f64 = b.iter().zip(&eta).map(|(x, e)| 0.5 * (x - e) * (x - e)).sum();
                let linf = b.iter().fold(0.0f64, |m, v| m.max(v.abs()));
                quad + mu * linf
            };
            let base = obj(&prox);
            for _ in 0..20 {
                let pert: Vec<f64> =
                    prox.iter().map(|v| v + rng.normal() * 0.05).collect();
                assert!(obj(&pert) >= base - 1e-9);
            }
        }
    }

    #[test]
    fn pava_produces_isotonic_means() {
        let z = [3.0, 1.0, 2.0];
        let u = pava_nonincreasing(&z);
        assert!((u[0] - 3.0).abs() < 1e-12);
        assert!((u[1] - 1.5).abs() < 1e-12);
        assert!((u[2] - 1.5).abs() < 1e-12);
        // already decreasing → identity
        let z2 = [5.0, 4.0, 1.0];
        assert_eq!(pava_nonincreasing(&z2), z2.to_vec());
        // all increasing → single pooled mean
        let z3 = [1.0, 2.0, 3.0];
        let u3 = pava_nonincreasing(&z3);
        for v in u3 {
            assert!((v - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn slope_prox_equals_soft_threshold_for_equal_weights() {
        let mut rng = Xoshiro256::seed_from_u64(33);
        for _ in 0..30 {
            let p = 1 + rng.below(15);
            let eta: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
            let lam = 0.7;
            let lambda = vec![lam; p];
            let got = prox_slope(&eta, &lambda, 1.0);
            let mut want = eta.clone();
            soft_threshold(&mut want, lam);
            for k in 0..p {
                assert!((got[k] - want[k]).abs() < 1e-10, "{got:?} vs {want:?}");
            }
        }
    }

    #[test]
    fn slope_prox_is_optimal_against_perturbations() {
        let mut rng = Xoshiro256::seed_from_u64(34);
        for trial in 0..40 {
            let p = 2 + rng.below(10);
            let eta: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
            let mut lambda: Vec<f64> = (0..p).map(|_| rng.uniform() * 1.5).collect();
            lambda.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
            let mu = 0.3 + rng.uniform();
            let got = prox_slope(&eta, &lambda, mu);
            let base = slope_prox_objective(&got, &eta, &lambda, mu);
            // random perturbations must not improve the objective
            for _ in 0..50 {
                let pert: Vec<f64> = got.iter().map(|v| v + rng.normal() * 0.03).collect();
                let o = slope_prox_objective(&pert, &eta, &lambda, mu);
                assert!(o >= base - 1e-8, "trial {trial}: {o} < {base}");
            }
            // coordinate sign pattern must match η where nonzero
            for k in 0..p {
                if got[k] != 0.0 {
                    assert!(got[k] * eta[k] >= 0.0);
                    assert!(got[k].abs() <= eta[k].abs() + 1e-12);
                }
            }
        }
    }

    #[test]
    fn slope_prox_ordering_preserved() {
        // |prox| ordering must follow |η| ordering (exchange property).
        let mut rng = Xoshiro256::seed_from_u64(35);
        for _ in 0..30 {
            let p = 3 + rng.below(8);
            let eta: Vec<f64> = (0..p).map(|_| rng.normal() * 2.0).collect();
            let mut lambda: Vec<f64> = (0..p).map(|_| rng.uniform()).collect();
            lambda.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
            let got = prox_slope(&eta, &lambda, 1.0);
            let mut idx: Vec<usize> = (0..p).collect();
            idx.sort_unstable_by(|&a, &b| eta[b].abs().partial_cmp(&eta[a].abs()).unwrap());
            for w in idx.windows(2) {
                assert!(
                    got[w[0]].abs() >= got[w[1]].abs() - 1e-9,
                    "ordering violated"
                );
            }
        }
    }
}
