//! Cyclical proximal block coordinate descent for Group-SVM (§4.3).
//!
//! One sweep costs the same as a full gradient thanks to incremental
//! maintenance of the margins `Xβ` (the paper's flop-accounting argument):
//! moving from group to group only the margin contribution of the touched
//! block is recomputed, and the smoothed dual weights `w^τ` follow from
//! the margins in O(n).
//!
//! Runs against the [`Backend`] trait: the per-group gradient `X_gᵀv` is
//! a set of column dots chunked over scoped workers
//! ([`crate::backend::par_col_dots`], honoring [`BlockCdParams::threads`]
//! and bit-identical at any thread count), so block CD shares the same
//! kernels as cutting-plane pricing.
//!
//! Includes the paper's active-set strategy: groups at zero that stay at
//! zero after a probe step are skipped in subsequent sweeps until the
//! final full sweep confirms stationarity.

use crate::backend::{par_col_dots, Backend};
use crate::fom::prox::prox_linf;

/// Block CD hyperparameters.
#[derive(Clone, Debug)]
pub struct BlockCdParams {
    /// Smoothing parameter τ.
    pub tau: f64,
    /// Stop when the largest coefficient move in a sweep is below this.
    pub tol: f64,
    /// Max full sweeps.
    pub max_sweeps: usize,
    /// Enable the active-set strategy.
    pub active_set: bool,
    /// Worker threads for the per-group gradient dots (1 = serial);
    /// results are identical for any thread count.
    pub threads: usize,
}

impl Default for BlockCdParams {
    fn default() -> Self {
        Self { tau: 0.2, tol: 1e-4, max_sweeps: 100, active_set: true, threads: 1 }
    }
}

/// Block CD output.
#[derive(Clone, Debug)]
pub struct BlockCdResult {
    pub beta: Vec<f64>,
    pub beta0: f64,
    /// Sweeps performed.
    pub sweeps: usize,
}

/// σ_max(X_gᵀX_g) for one group via power iteration on the group columns.
fn group_sigma_sq(backend: &dyn Backend, group: &[usize], iters: usize) -> f64 {
    let n = backend.rows();
    let k = group.len();
    let mut v = vec![1.0 / (k as f64).sqrt(); k];
    let mut xv = vec![0.0; n];
    let mut lam = 1.0;
    for _ in 0..iters {
        xv.fill(0.0);
        for (t, &j) in group.iter().enumerate() {
            if v[t] != 0.0 {
                backend.col_axpy(j, v[t], &mut xv);
            }
        }
        let mut w = vec![0.0; k];
        for (t, &j) in group.iter().enumerate() {
            w[t] = backend.col_dot(j, &xv);
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-30);
        lam = norm;
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / norm;
        }
    }
    lam
}

/// Run block CD on the smoothed Group-SVM problem.
pub fn block_cd(
    backend: &dyn Backend,
    y: &[f64],
    groups: &[Vec<usize>],
    lambda: f64,
    params: &BlockCdParams,
    init: Option<(&[f64], f64)>,
) -> BlockCdResult {
    let n = backend.rows();
    let p = backend.cols();
    let tau = params.tau;
    let (mut beta, mut beta0) = match init {
        Some((b, b0)) => (b.to_vec(), b0),
        None => (vec![0.0; p], 0.0),
    };
    // Lipschitz per group: σ_max(X_gᵀ X_g)/(4τ), with safety margin.
    let lips: Vec<f64> = groups
        .iter()
        .map(|g| (group_sigma_sq(backend, g, 20) / (4.0 * tau)).max(1e-12) * 1.05)
        .collect();
    let l0 = (n as f64 / (4.0 * tau)) * 1.05; // intercept block (column of 1s)

    // margins Xβ (maintained incrementally)
    let mut xb = vec![0.0; n];
    for (j, &b) in beta.iter().enumerate() {
        if b != 0.0 {
            backend.col_axpy(j, b, &mut xb);
        }
    }
    let mut active: Vec<bool> = vec![true; groups.len()];
    let mut sweeps = 0;
    // v_i = y_i (1 + w_i)/2 with w_i = clip(z_i/2τ) and z = 1 − y∘(xb+β₀)
    let mut v = vec![0.0; n];
    let refresh_v = |xb: &[f64], beta0: f64, v: &mut [f64]| {
        for i in 0..n {
            let z = 1.0 - y[i] * (xb[i] + beta0);
            let w = (z / (2.0 * tau)).clamp(-1.0, 1.0);
            v[i] = 0.5 * y[i] * (1.0 + w);
        }
    };

    for sweep in 0..params.max_sweeps {
        sweeps = sweep + 1;
        let final_pass = sweep + 1 == params.max_sweeps;
        let mut max_move = 0.0f64;
        refresh_v(&xb, beta0, &mut v);
        for (g_idx, group) in groups.iter().enumerate() {
            if params.active_set && !active[g_idx] && !final_pass && sweep % 10 != 9 {
                continue; // inactive group (re-probed every 10th sweep)
            }
            // gradient of F^τ restricted to the group: −X_gᵀ v, chunked
            // over workers like the pricing matvec
            let lg = lips[g_idx];
            let dots = par_col_dots(backend, params.threads, group, &v);
            let mut target: Vec<f64> = group
                .iter()
                .zip(&dots)
                .map(|(&j, &d)| beta[j] + d / lg)
                .collect();
            target = prox_linf(&target, lambda / lg);
            // apply the move, maintaining margins and v
            let mut moved = false;
            for (t, &j) in group.iter().enumerate() {
                let delta = target[t] - beta[j];
                if delta != 0.0 {
                    backend.col_axpy(j, delta, &mut xb);
                    beta[j] = target[t];
                    max_move = max_move.max(delta.abs());
                    moved = true;
                }
            }
            if moved {
                refresh_v(&xb, beta0, &mut v);
                active[g_idx] = true;
            } else if params.active_set
                && group.iter().all(|&j| beta[j] == 0.0)
            {
                active[g_idx] = false;
            }
        }
        // intercept block
        let g0: f64 = -v.iter().sum::<f64>();
        let d0 = -g0 / l0;
        if d0 != 0.0 {
            beta0 += d0;
            max_move = max_move.max(d0.abs());
        }
        if max_move <= params.tol {
            break;
        }
    }
    BlockCdResult { beta, beta0, sweeps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::synthetic::{generate_group, GroupSpec};
    use crate::fom::objective::group_objective;
    use crate::rng::Xoshiro256;

    fn setup() -> (crate::data::synthetic::GroupDataset, f64) {
        let mut rng = Xoshiro256::seed_from_u64(61);
        let spec = GroupSpec {
            n: 60,
            n_groups: 12,
            group_size: 5,
            k0_groups: 3,
            rho: 0.2,
            standardize: true,
        };
        let gd = generate_group(&spec, &mut rng);
        let lam = 0.2 * gd.data.lambda_max_group(&gd.groups);
        (gd, lam)
    }

    #[test]
    fn block_cd_improves_objective() {
        let (gd, lam) = setup();
        let backend = NativeBackend::new(&gd.data.x);
        let res = block_cd(&backend, &gd.data.y, &gd.groups, lam, &BlockCdParams::default(), None);
        let zero =
            group_objective(&backend, &gd.data.y, &vec![0.0; gd.data.p()], 0.0, lam, &gd.groups);
        let got = group_objective(&backend, &gd.data.y, &res.beta, res.beta0, lam, &gd.groups);
        assert!(got < zero, "{got} !< {zero}");
    }

    #[test]
    fn block_cd_selects_informative_groups() {
        let (gd, lam) = setup();
        let backend = NativeBackend::new(&gd.data.x);
        let params = BlockCdParams { max_sweeps: 300, tol: 1e-6, ..Default::default() };
        let res = block_cd(&backend, &gd.data.y, &gd.groups, lam, &params, None);
        // informative groups (0..3) should carry most mass
        let mass = |g: &Vec<usize>| g.iter().map(|&j| res.beta[j].abs()).sum::<f64>();
        let info: f64 = gd.groups[..3].iter().map(mass).sum();
        let noise: f64 = gd.groups[3..].iter().map(mass).sum();
        assert!(info > noise, "info {info} noise {noise}");
    }

    #[test]
    fn block_cd_matches_fista_objective_roughly() {
        let (gd, lam) = setup();
        let backend = NativeBackend::new(&gd.data.x);
        let params = BlockCdParams { max_sweeps: 500, tol: 1e-7, ..Default::default() };
        let cd = block_cd(&backend, &gd.data.y, &gd.groups, lam, &params, None);
        let fista_res = crate::fom::fista(
            &backend,
            &gd.data.y,
            &crate::fom::Penalty::GroupLinf { lambda: lam, groups: gd.groups.clone() },
            &crate::fom::FistaParams { max_iters: 2000, eta: 1e-8, ..Default::default() },
            None,
        );
        let o_cd = group_objective(&backend, &gd.data.y, &cd.beta, cd.beta0, lam, &gd.groups);
        let o_fi = group_objective(
            &backend,
            &gd.data.y,
            &fista_res.beta,
            fista_res.beta0,
            lam,
            &gd.groups,
        );
        let rel = (o_cd - o_fi).abs() / o_fi.max(1e-9);
        assert!(rel < 0.05, "cd {o_cd} fista {o_fi} rel {rel}");
    }

    #[test]
    fn active_set_gives_same_answer() {
        let (gd, lam) = setup();
        let backend = NativeBackend::new(&gd.data.x);
        let p1 =
            BlockCdParams { max_sweeps: 200, tol: 1e-6, active_set: true, ..Default::default() };
        let p2 = BlockCdParams { active_set: false, ..p1.clone() };
        let a = block_cd(&backend, &gd.data.y, &gd.groups, lam, &p1, None);
        let b = block_cd(&backend, &gd.data.y, &gd.groups, lam, &p2, None);
        let oa = group_objective(&backend, &gd.data.y, &a.beta, a.beta0, lam, &gd.groups);
        let ob = group_objective(&backend, &gd.data.y, &b.beta, b.beta0, lam, &gd.groups);
        assert!((oa - ob).abs() / ob.max(1e-9) < 0.02, "{oa} vs {ob}");
    }

    // threads=1 vs threads=4 bitwise identity for the Backend-based
    // block CD (and the seed built on it) is covered end-to-end by
    // tests/initialization.rs::refactored_fom_paths_are_thread_identical_end_to_end.
}
