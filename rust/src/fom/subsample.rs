//! Subsample-and-average heuristics for large n (§4.4.2–4.4.3).
//!
//! The first-order methods become gradient-bound when n is large; the
//! paper instead solves the problem on subsamples `A_j` (with λ rescaled
//! by `|A|/n`), averages the estimators for variance reduction, and stops
//! once the running average stabilizes. The subsample solves are
//! embarrassingly parallel — here they run on `std::thread` workers; the
//! FISTA gradients inside each solve and the final margin scans ride the
//! shared [`Backend`] kernels.

use crate::backend::{Backend, NativeBackend};
use crate::data::{Dataset, Design};
use crate::fom::fista::{fista, FistaParams, Penalty};
use crate::fom::screening::correlation_screen;
use crate::rng::Xoshiro256;

/// Parameters of the subsampling heuristic.
#[derive(Clone, Debug)]
pub struct SubsampleParams {
    /// Subsample size n₀ (paper: 10·p for the large-n regime).
    pub n0: usize,
    /// Stop when ‖β̄_Q − β̄_{Q−1}‖ ≤ μ_tol (paper: 0.1, or 0.5 sparse).
    pub mu_tol: f64,
    /// Max number of subsamples (paper: n/n₀).
    pub q_max: usize,
    /// Worker threads.
    pub threads: usize,
    /// Optional correlation screening within each subsample (§4.4.3):
    /// keep the top `screen_k` features (0 = off).
    pub screen_k: usize,
    /// FISTA settings for the subsample solves.
    pub fista: FistaParams,
}

impl Default for SubsampleParams {
    fn default() -> Self {
        Self {
            n0: 1000,
            mu_tol: 1e-1,
            q_max: 16,
            threads: 4,
            screen_k: 0,
            fista: FistaParams::default(),
        }
    }
}

/// Result of the averaged-subsample estimator.
#[derive(Clone, Debug)]
pub struct SubsampleResult {
    /// Averaged coefficients β̄_Q.
    pub beta: Vec<f64>,
    /// Averaged intercept.
    pub beta0: f64,
    /// Number of subsamples actually used.
    pub q_used: usize,
}

/// One subsample solve: draw `n0` rows, rescale λ, FISTA (optionally after
/// correlation screening), scatter back to ℝᵖ.
fn solve_subsample(
    ds: &Dataset,
    lambda: f64,
    params: &SubsampleParams,
    seed: u64,
) -> (Vec<f64>, f64) {
    let n = ds.n();
    let p = ds.p();
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let n0 = params.n0.min(n);
    let rows = rng.sample_indices(n, n0);
    let sub_x: Design = ds.x.subset_rows(&rows);
    let sub_y: Vec<f64> = rows.iter().map(|&i| ds.y[i]).collect();
    let lam_scaled = lambda * n0 as f64 / n as f64;

    if params.screen_k > 0 && params.screen_k < p {
        // Serial screening wrapper on purpose: this runs inside a
        // subsample worker thread and must not nest thread pools.
        let cols = correlation_screen(&sub_x, &sub_y, params.screen_k);
        let xx = sub_x.subset_cols(&cols);
        let backend = NativeBackend::new(&xx);
        let res = fista(&backend, &sub_y, &Penalty::L1(lam_scaled), &params.fista, None);
        let mut beta = vec![0.0; p];
        for (k, &j) in cols.iter().enumerate() {
            beta[j] = res.beta[k];
        }
        (beta, res.beta0)
    } else {
        let backend = NativeBackend::new(&sub_x);
        let res = fista(&backend, &sub_y, &Penalty::L1(lam_scaled), &params.fista, None);
        (res.beta, res.beta0)
    }
}

/// Run the subsample-and-average heuristic (§4.4.2; with `screen_k > 0`
/// this is the large-n-large-p variant of §4.4.3).
pub fn subsample_average(
    ds: &Dataset,
    lambda: f64,
    params: &SubsampleParams,
    seed: u64,
) -> SubsampleResult {
    let p = ds.p();
    let mut sum_beta = vec![0.0; p];
    let mut sum_beta0 = 0.0;
    let mut prev_avg: Option<Vec<f64>> = None;
    let mut q_used = 0;

    let mut next_seed = seed;
    'outer: while q_used < params.q_max {
        // Launch one batch of worker threads.
        let batch = params.threads.min(params.q_max - q_used).max(1);
        let results: Vec<(Vec<f64>, f64)> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(batch);
            for b in 0..batch {
                let s = next_seed + b as u64;
                let ds_ref = &*ds;
                let params_ref = &*params;
                handles.push(scope.spawn(move || solve_subsample(ds_ref, lambda, params_ref, s)));
            }
            handles.into_iter().map(|h| h.join().expect("subsample worker panicked")).collect()
        });
        next_seed += batch as u64;

        for (beta, beta0) in results {
            q_used += 1;
            for (s, b) in sum_beta.iter_mut().zip(&beta) {
                *s += b;
            }
            sum_beta0 += beta0;
            let avg: Vec<f64> = sum_beta.iter().map(|s| s / q_used as f64).collect();
            if let Some(prev) = &prev_avg {
                let delta: f64 = avg
                    .iter()
                    .zip(prev)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt();
                if delta <= params.mu_tol {
                    prev_avg = Some(avg);
                    break 'outer;
                }
            }
            prev_avg = Some(avg);
        }
    }
    let beta = prev_avg.unwrap_or_else(|| vec![0.0; p]);
    SubsampleResult { beta, beta0: sum_beta0 / q_used.max(1) as f64, q_used }
}

/// Sample indices whose hinge loss is positive at `(β, β₀)` — the paper's
/// initializer for the constraint-generation working set `I`. The margin
/// matvec runs through the shared [`Backend`].
pub fn violated_samples(
    backend: &dyn Backend,
    y: &[f64],
    beta: &[f64],
    beta0: f64,
    slack: f64,
) -> Vec<usize> {
    let n = backend.rows();
    let mut xb = vec![0.0; n];
    backend.xb(beta, &mut xb);
    (0..n)
        .filter(|&i| 1.0 - y[i] * (xb[i] + beta0) > -slack)
        .collect()
}

/// Like [`violated_samples`] but capped: returns the `cap` *most violated*
/// samples. A noisy first-order estimate can flag thousands of samples on
/// large-n data; seeding constraint generation with all of them inflates
/// the LP basis (O(|I|³) factorizations) for no benefit — the CNG rounds
/// bring in whatever the initializer missed.
pub fn violated_samples_capped(
    backend: &dyn Backend,
    y: &[f64],
    beta: &[f64],
    beta0: f64,
    slack: f64,
    cap: usize,
) -> Vec<usize> {
    let n = backend.rows();
    let mut xb = vec![0.0; n];
    backend.xb(beta, &mut xb);
    let mut scored: Vec<(usize, f64)> = (0..n)
        .filter_map(|i| {
            let z = 1.0 - y[i] * (xb[i] + beta0);
            if z > -slack {
                Some((i, z))
            } else {
                None
            }
        })
        .collect();
    if scored.len() > cap {
        scored.sort_unstable_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(cap);
    }
    scored.into_iter().map(|(i, _)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_l1, SyntheticSpec};

    fn big_n_dataset() -> Dataset {
        let mut rng = Xoshiro256::seed_from_u64(81);
        let spec = SyntheticSpec { n: 1200, p: 25, k0: 5, rho: 0.1, standardize: true };
        generate_l1(&spec, &mut rng)
    }

    #[test]
    fn subsample_average_stabilizes_and_is_sensible() {
        let ds = big_n_dataset();
        let lambda = 0.01 * ds.lambda_max_l1();
        let params = SubsampleParams { n0: 250, q_max: 8, threads: 4, ..Default::default() };
        let res = subsample_average(&ds, lambda, &params, 7);
        assert!(res.q_used >= 2);
        // informative features should dominate
        let info: f64 = res.beta[..5].iter().map(|v| v.abs()).sum();
        let noise: f64 = res.beta[5..].iter().map(|v| v.abs()).sum();
        assert!(info > noise, "info {info} noise {noise}");
    }

    #[test]
    fn subsample_with_screening_matches_support() {
        let ds = big_n_dataset();
        let lambda = 0.01 * ds.lambda_max_l1();
        let params = SubsampleParams {
            n0: 250,
            q_max: 6,
            threads: 3,
            screen_k: 15,
            ..Default::default()
        };
        let res = subsample_average(&ds, lambda, &params, 11);
        let info: f64 = res.beta[..5].iter().map(|v| v.abs()).sum();
        assert!(info > 0.0);
    }

    #[test]
    fn violated_samples_detects_margin_violations() {
        let ds = big_n_dataset();
        let backend = NativeBackend::new(&ds.x);
        // zero coefficients: every sample violates (hinge = 1)
        let all = violated_samples(&backend, &ds.y, &vec![0.0; ds.p()], 0.0, 0.0);
        assert_eq!(all.len(), ds.n());
        // a good separator from FISTA violates far fewer
        let lambda = 0.01 * ds.lambda_max_l1();
        let res = fista(
            &backend,
            &ds.y,
            &Penalty::L1(lambda),
            &FistaParams { max_iters: 500, eta: 1e-6, ..Default::default() },
            None,
        );
        let few = violated_samples(&backend, &ds.y, &res.beta, res.beta0, 0.0);
        assert!(few.len() < ds.n(), "classifier should satisfy some margins");
        // the capped variant keeps the worst offenders first
        let capped = violated_samples_capped(&backend, &ds.y, &vec![0.0; ds.p()], 0.0, 0.0, 100);
        assert_eq!(capped.len(), 100);
    }

    #[test]
    fn subsample_fista_threads_are_bit_identical() {
        // the inner FISTA gradients ride par_xtv: chunking must not
        // change a single bit of the averaged estimator
        let ds = big_n_dataset();
        let lambda = 0.02 * ds.lambda_max_l1();
        let base = SubsampleParams { n0: 200, q_max: 4, threads: 2, ..Default::default() };
        let serial = subsample_average(&ds, lambda, &base, 3);
        let par_params = SubsampleParams {
            fista: FistaParams { threads: 4, ..Default::default() },
            ..base
        };
        let par = subsample_average(&ds, lambda, &par_params, 3);
        assert_eq!(serial.q_used, par.q_used);
        assert_eq!(serial.beta0, par.beta0);
        assert_eq!(serial.beta, par.beta);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = big_n_dataset();
        let lambda = 0.02 * ds.lambda_max_l1();
        let params = SubsampleParams { n0: 200, q_max: 4, threads: 2, ..Default::default() };
        let a = subsample_average(&ds, lambda, &params, 3);
        let b = subsample_average(&ds, lambda, &params, 3);
        assert_eq!(a.q_used, b.q_used);
        for (x, y) in a.beta.iter().zip(&b.beta) {
            assert_eq!(x, y);
        }
    }
}
