//! Row-major dense matrix and the two matvec kernels on the hot path.
//!
//! `Matrix` stores `X` row-major (`n` samples × `p` features), which makes
//! `Xβ` a streaming row·vector loop and `Xᵀv` an axpy accumulation — both
//! single-pass over the matrix, i.e. memory-bandwidth bound.

use crate::linalg::{axpy, dot, fmadd};

/// Dense row-major `rows × cols` f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Number of rows (samples).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (features).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    /// Element write.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Flat row-major view of the whole buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Column `j` copied into a fresh vector (strided gather).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// `out = X v` (length `rows`).
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = dot(self.row(i), v);
        }
    }

    /// `out = Xᵀ v` (length `cols`): single streaming pass over X, no
    /// strided access. Delegates to [`Matrix::tmatvec_range`] over the
    /// full column range so the serial product and any chunked parallel
    /// pricing of it run the identical kernel (bit-identical results).
    pub fn tmatvec(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(out.len(), self.cols);
        self.tmatvec_range(v, 0, out);
    }

    /// Column-range slice of `Xᵀ v`: `out[k] = (Xᵀv)[j0 + k]`.
    ///
    /// Rows are processed in blocks of four with each column's partial
    /// sum carried through the block in registers — four contiguous row
    /// slices per iteration, which autovectorizes to wide FMAs. The
    /// blocking spans the full row dimension whatever the column range,
    /// and each output accumulates rows in ascending order, so chunked
    /// parallel pricing reproduces the serial `tmatvec` bit for bit.
    /// All-zero blocks of `v` are skipped (dual vectors are sparse);
    /// a zero weight inside a mixed block contributes exactly 0.0, so
    /// the skip never changes the value.
    pub fn tmatvec_range(&self, v: &[f64], j0: usize, out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert!(j0 + out.len() <= self.cols);
        out.fill(0.0);
        let w = out.len();
        if w == 0 {
            return;
        }
        let blocks = self.rows / 4;
        for blk in 0..blocks {
            let i = 4 * blk;
            let (v0, v1, v2, v3) = (v[i], v[i + 1], v[i + 2], v[i + 3]);
            if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                continue;
            }
            let r0 = &self.data[i * self.cols + j0..i * self.cols + j0 + w];
            let r1 = &self.data[(i + 1) * self.cols + j0..(i + 1) * self.cols + j0 + w];
            let r2 = &self.data[(i + 2) * self.cols + j0..(i + 2) * self.cols + j0 + w];
            let r3 = &self.data[(i + 3) * self.cols + j0..(i + 3) * self.cols + j0 + w];
            for k in 0..w {
                let s = fmadd(v0, r0[k], out[k]);
                let s = fmadd(v1, r1[k], s);
                let s = fmadd(v2, r2[k], s);
                out[k] = fmadd(v3, r3[k], s);
            }
        }
        for i in 4 * blocks..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            let row = &self.data[i * self.cols + j0..i * self.cols + j0 + w];
            for (o, x) in out.iter_mut().zip(row) {
                *o = fmadd(vi, *x, *o);
            }
        }
    }

    /// `Xᵀ v` restricted to a subset of rows: `out = Σ_{i∈rows} v[k] x_i`
    /// where `v[k]` aligns with `rows[k]`. Used by restricted-constraint
    /// pricing where the dual vector π only lives on the working set I.
    pub fn tmatvec_rows(&self, rows: &[usize], v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), rows.len());
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        for (k, &i) in rows.iter().enumerate() {
            axpy(v[k], self.row(i), out);
        }
    }

    /// Dot of one row with a vector indexed by a column subset:
    /// `Σ_{k} x[i, cols[k]] * beta[k]`.
    pub fn row_dot_cols(&self, i: usize, cols: &[usize], beta: &[f64]) -> f64 {
        debug_assert_eq!(cols.len(), beta.len());
        let r = self.row(i);
        let mut s = 0.0;
        for (k, &j) in cols.iter().enumerate() {
            s += r[j] * beta[k];
        }
        s
    }

    /// Scale every column to unit L2 norm (the paper standardizes features
    /// this way). Returns the scale factors applied (1/‖col‖).
    pub fn standardize_columns(&mut self) -> Vec<f64> {
        let mut scales = vec![1.0; self.cols];
        for j in 0..self.cols {
            let mut s = 0.0;
            for i in 0..self.rows {
                let v = self.get(i, j);
                s += v * v;
            }
            let nrm = s.sqrt();
            if nrm > 0.0 {
                scales[j] = 1.0 / nrm;
            }
        }
        for i in 0..self.rows {
            let row = self.row_mut(i);
            for j in 0..row.len() {
                row[j] *= scales[j];
            }
        }
        scales
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Matrix {
        Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
    }

    #[test]
    fn accessors() {
        let m = sample();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(m.col(1), vec![2.0, 5.0]);
    }

    #[test]
    fn matvec_and_tmatvec() {
        let m = sample();
        let mut out = vec![0.0; 2];
        m.matvec(&[1.0, 0.0, -1.0], &mut out);
        assert_eq!(out, vec![-2.0, -2.0]);
        let mut out_t = vec![0.0; 3];
        m.tmatvec(&[1.0, -1.0], &mut out_t);
        assert_eq!(out_t, vec![-3.0, -3.0, -3.0]);
    }

    #[test]
    fn tmatvec_range_chunks_reassemble_bitwise() {
        // 11 rows exercises both the 4-row blocks and the remainder, with
        // zero weights landing inside mixed blocks; every chunking of the
        // column range must reassemble the full product bit for bit
        let (rows, cols) = (11, 7);
        let mut m = Matrix::zeros(rows, cols);
        let mut state = 1u64;
        for i in 0..rows {
            for j in 0..cols {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                m.set(i, j, ((state >> 33) as f64 / (1u64 << 31) as f64) - 0.5);
            }
        }
        let v: Vec<f64> =
            (0..rows).map(|i| if i % 3 == 0 { 0.0 } else { i as f64 - 4.5 }).collect();
        let mut full = vec![0.0; cols];
        m.tmatvec(&v, &mut full);
        for split in 0..=cols {
            let mut lo = vec![0.0; split];
            let mut hi = vec![0.0; cols - split];
            m.tmatvec_range(&v, 0, &mut lo);
            m.tmatvec_range(&v, split, &mut hi);
            let got: Vec<f64> = lo.into_iter().chain(hi).collect();
            assert_eq!(got, full, "split at {split}");
        }
    }

    #[test]
    fn tmatvec_rows_subset() {
        let m = sample();
        let mut out = vec![0.0; 3];
        m.tmatvec_rows(&[1], &[2.0], &mut out);
        assert_eq!(out, vec![8.0, 10.0, 12.0]);
    }

    #[test]
    fn row_dot_cols_subset() {
        let m = sample();
        let v = m.row_dot_cols(0, &[0, 2], &[2.0, 1.0]);
        assert_eq!(v, 2.0 + 3.0);
    }

    #[test]
    fn standardize_unit_columns() {
        let mut m = sample();
        m.standardize_columns();
        for j in 0..3 {
            let c = m.col(j);
            let n: f64 = c.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn standardize_handles_zero_column() {
        let mut m = Matrix::zeros(3, 2);
        m.set(0, 0, 2.0);
        let s = m.standardize_columns();
        assert_eq!(s[1], 1.0); // zero column untouched
        assert!((m.get(0, 0) - 1.0).abs() < 1e-12);
    }
}
