//! Dense linear algebra substrate.
//!
//! The LP basis factorizations and the first-order methods need a small
//! amount of dense linear algebra; the build image has no BLAS/LAPACK
//! crates, so the kernels live here:
//!
//! * [`dense`] — row-major matrix type with the matvec kernels used by the
//!   native compute backend (`Xβ`, `Xᵀv`).
//! * [`lu`] — LU factorization with partial pivoting and triangular solves,
//!   used by the simplex basis.

pub mod dense;
pub mod lu;

pub use dense::Matrix;
pub use lu::Lu;

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// L1 norm.
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Infinity norm.
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// `acc + a·b`, fused into one rounding when the build enables the FMA
/// target feature, plain multiply-add otherwise.
///
/// The fallback is deliberately *not* `f64::mul_add` — without the
/// instruction that call emulates fused rounding in software at many
/// times the cost. The two paths differ only in the last ulp, which is
/// why cross-layout (dense vs CSC) agreement is specified at ≤1e-12
/// rather than bitwise; thread-count determinism is exact either way,
/// because chunking never changes which kernel computes a given output
/// or its accumulation order (see docs/kernels.md).
#[inline(always)]
pub(crate) fn fmadd(a: f64, b: f64, acc: f64) -> f64 {
    #[cfg(target_feature = "fma")]
    {
        a.mul_add(b, acc)
    }
    #[cfg(not(target_feature = "fma"))]
    {
        acc + a * b
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // Eight independent accumulators: the serial FP dependency chain is
    // what limits the naive loop, and eight lanes let the autovectorizer
    // keep two 4-wide vector accumulators in flight. Lane assignment and
    // the final reduction order are fixed for a given length, so the
    // result is deterministic.
    let n = a.len();
    let chunks = n / 8;
    let mut s = [0.0f64; 8];
    for k in 0..chunks {
        let i = 8 * k;
        for (l, sl) in s.iter_mut().enumerate() {
            *sl = fmadd(a[i + l], b[i + l], *sl);
        }
    }
    let mut acc = ((s[0] + s[4]) + (s[1] + s[5])) + ((s[2] + s[6]) + (s[3] + s[7]));
    for i in 8 * chunks..n {
        acc = fmadd(a[i], b[i], acc);
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_and_dot() {
        let x = [3.0, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
        assert!((norm1(&x) - 7.0).abs() < 1e-12);
        assert!((norm_inf(&x) - 4.0).abs() < 1e-12);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn dot_matches_naive_on_odd_lengths() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_accumulates() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
