//! Dense linear algebra substrate.
//!
//! The LP basis factorizations and the first-order methods need a small
//! amount of dense linear algebra; the build image has no BLAS/LAPACK
//! crates, so the kernels live here:
//!
//! * [`dense`] — row-major matrix type with the matvec kernels used by the
//!   native compute backend (`Xβ`, `Xᵀv`).
//! * [`lu`] — LU factorization with partial pivoting and triangular solves,
//!   used by the simplex basis.

pub mod dense;
pub mod lu;

pub use dense::Matrix;
pub use lu::Lu;

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// L1 norm.
pub fn norm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Infinity norm.
pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: measurably faster than the naive loop
    // at the sizes the simplex uses, and deterministic.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    if alpha == 0.0 {
        return;
    }
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_and_dot() {
        let x = [3.0, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
        assert!((norm1(&x) - 7.0).abs() < 1e-12);
        assert!((norm_inf(&x) - 4.0).abs() < 1e-12);
        assert!((dot(&x, &x) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn dot_matches_naive_on_odd_lengths() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5 - 3.0).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_accumulates() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [3.0, 5.0, 7.0]);
    }
}
