//! LU factorization with partial pivoting.
//!
//! The simplex basis matrix `B` is refactorized periodically; in between,
//! product-form eta updates (see `simplex::basis`) are applied on top of
//! the triangular solves here. We need both directions:
//!
//! * FTRAN: solve `B x = b`   → [`Lu::solve`]
//! * BTRAN: solve `Bᵀ x = b`  → [`Lu::solve_transposed`]

use crate::linalg::Matrix;

/// LU decomposition `P A = L U` of a square matrix, stored packed
/// (unit-lower L below the diagonal, U on and above it).
#[derive(Clone, Debug)]
pub struct Lu {
    n: usize,
    /// Packed LU factors, row-major.
    lu: Vec<f64>,
    /// Row permutation: `perm[k]` = original row index in position `k`.
    perm: Vec<usize>,
    /// Whether factorization detected (numerical) singularity.
    singular: bool,
}

impl Lu {
    /// Factorize a dense row-major `n×n` matrix given as a flat slice.
    pub fn factorize_flat(n: usize, a: &[f64]) -> Self {
        assert_eq!(a.len(), n * n);
        let mut lu = a.to_vec();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut singular = false;

        for k in 0..n {
            // Partial pivot: largest |entry| in column k, rows k..n.
            let mut piv = k;
            let mut best = lu[k * n + k].abs();
            for i in (k + 1)..n {
                let v = lu[i * n + k].abs();
                if v > best {
                    best = v;
                    piv = i;
                }
            }
            if best < 1e-13 {
                singular = true;
                // Leave a tiny pivot in place so solves don't divide by 0.
                if lu[k * n + k] == 0.0 {
                    lu[k * n + k] = 1e-13;
                }
                continue;
            }
            if piv != k {
                perm.swap(k, piv);
                for j in 0..n {
                    lu.swap(k * n + j, piv * n + j);
                }
            }
            let pivot = lu[k * n + k];
            for i in (k + 1)..n {
                let m = lu[i * n + k] / pivot;
                lu[i * n + k] = m;
                if m != 0.0 {
                    // Row update: row_i -= m * row_k  (columns k+1..n)
                    let (head, tail) = lu.split_at_mut(i * n);
                    let row_k = &head[k * n + k + 1..k * n + n];
                    let row_i = &mut tail[k + 1..n];
                    for (ri, rk) in row_i.iter_mut().zip(row_k) {
                        *ri -= m * rk;
                    }
                }
            }
        }
        Self { n, lu, perm, singular }
    }

    /// Factorize a [`Matrix`] (must be square).
    pub fn factorize(a: &Matrix) -> Self {
        assert_eq!(a.rows(), a.cols());
        Self::factorize_flat(a.rows(), a.as_slice())
    }

    /// Dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Whether the matrix was detected singular during elimination.
    pub fn is_singular(&self) -> bool {
        self.singular
    }

    /// FTRAN: solve `A x = b` in place (`b` becomes `x`).
    pub fn solve(&self, b: &mut [f64]) {
        let n = self.n;
        assert_eq!(b.len(), n);
        // Apply permutation: x = P b.
        let mut x = vec![0.0; n];
        for k in 0..n {
            x[k] = b[self.perm[k]];
        }
        // Forward solve L y = P b (unit diagonal).
        for i in 1..n {
            let mut s = x[i];
            let row = &self.lu[i * n..i * n + i];
            for (j, lij) in row.iter().enumerate() {
                s -= lij * x[j];
            }
            x[i] = s;
        }
        // Back solve U x = y.
        for i in (0..n).rev() {
            let mut s = x[i];
            let row = &self.lu[i * n + i + 1..(i + 1) * n];
            for (off, uij) in row.iter().enumerate() {
                s -= uij * x[i + 1 + off];
            }
            x[i] = s / self.lu[i * n + i];
        }
        b.copy_from_slice(&x);
    }

    /// BTRAN: solve `Aᵀ x = b` in place.
    ///
    /// From `P A = L U` we get `Aᵀ Pᵀ = Uᵀ Lᵀ`, so `Aᵀ x = b` is solved by
    /// `Uᵀ z = b`, `Lᵀ w = z`, `x = Pᵀ w`.
    ///
    /// Both substitutions are written *outer-product* style so the inner
    /// loop streams a contiguous **row** of the packed LU factor — the
    /// natural `x_i −= Σ_j lu[j·n+i]·x_j` form strides by `n` per element
    /// and was the top cache-miss site in the dual simplex profile (see
    /// EXPERIMENTS.md §Perf).
    pub fn solve_transposed(&self, b: &mut [f64]) {
        let n = self.n;
        assert_eq!(b.len(), n);
        let mut z = b.to_vec();
        // Forward solve Uᵀ z = b: once z[j] is final, subtract its
        // contribution from all later equations using U's row j.
        for j in 0..n {
            let zj = z[j] / self.lu[j * n + j];
            z[j] = zj;
            if zj != 0.0 {
                let row = &self.lu[j * n + j + 1..(j + 1) * n];
                let (_, tail) = z.split_at_mut(j + 1);
                for (zi, uji) in tail.iter_mut().zip(row) {
                    *zi -= uji * zj;
                }
            }
        }
        // Back solve Lᵀ w = z (unit diagonal): once w[j] is final,
        // subtract via L's row j (entries 0..j), contiguous again.
        for j in (0..n).rev() {
            let wj = z[j];
            if wj != 0.0 {
                let row = &self.lu[j * n..j * n + j];
                let (head, _) = z.split_at_mut(j);
                for (zi, lji) in head.iter_mut().zip(row) {
                    *zi -= lji * wj;
                }
            }
        }
        // x = Pᵀ w: x[perm[k]] = w[k].
        for k in 0..n {
            b[self.perm[k]] = z[k];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn matvec_flat(n: usize, a: &[f64], x: &[f64]) -> Vec<f64> {
        (0..n)
            .map(|i| (0..n).map(|j| a[i * n + j] * x[j]).sum())
            .collect()
    }

    fn tmatvec_flat(n: usize, a: &[f64], x: &[f64]) -> Vec<f64> {
        (0..n)
            .map(|j| (0..n).map(|i| a[i * n + j] * x[i]).sum())
            .collect()
    }

    #[test]
    fn solve_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let lu = Lu::factorize_flat(2, &a);
        let mut b = vec![3.0, -4.0];
        lu.solve(&mut b);
        assert_eq!(b, vec![3.0, -4.0]);
    }

    #[test]
    fn solve_small_known() {
        // A = [[2,1],[1,3]], b = [5, 10] => x = [1, 3]
        let a = vec![2.0, 1.0, 1.0, 3.0];
        let lu = Lu::factorize_flat(2, &a);
        let mut b = vec![5.0, 10.0];
        lu.solve(&mut b);
        assert!((b[0] - 1.0).abs() < 1e-12);
        assert!((b[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_requires_pivoting() {
        // Leading zero pivot forces a row swap.
        let a = vec![0.0, 1.0, 1.0, 0.0];
        let lu = Lu::factorize_flat(2, &a);
        assert!(!lu.is_singular());
        let mut b = vec![2.0, 3.0];
        lu.solve(&mut b);
        assert!((b[0] - 3.0).abs() < 1e-12);
        assert!((b[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn random_roundtrip_ftran_btran() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        for n in [1usize, 2, 3, 5, 17, 40, 80] {
            // Diagonally dominated random matrix => well conditioned.
            let mut a = vec![0.0; n * n];
            for i in 0..n {
                for j in 0..n {
                    a[i * n + j] = rng.normal();
                }
                a[i * n + i] += n as f64;
            }
            let lu = Lu::factorize_flat(n, &a);
            assert!(!lu.is_singular());
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();

            // FTRAN
            let mut b = matvec_flat(n, &a, &x_true);
            lu.solve(&mut b);
            for (xi, ti) in b.iter().zip(&x_true) {
                assert!((xi - ti).abs() < 1e-8, "n={n}");
            }
            // BTRAN
            let mut bt = tmatvec_flat(n, &a, &x_true);
            lu.solve_transposed(&mut bt);
            for (xi, ti) in bt.iter().zip(&x_true) {
                assert!((xi - ti).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn detects_singular() {
        let a = vec![1.0, 2.0, 2.0, 4.0];
        let lu = Lu::factorize_flat(2, &a);
        assert!(lu.is_singular());
    }
}
