//! Minimal error plumbing (the offline image carries no `anyhow`).
//!
//! [`Error`] is a string-message error that any `std::error::Error` converts
//! into via `?`; [`Context`] adds `anyhow`-style `.context(..)` /
//! `.with_context(..)` on `Result` and `Option`. The [`crate::err!`],
//! [`crate::bail!`] and [`crate::ensure!`] macros mirror their `anyhow`
//! namesakes.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A boxed human-readable error message.
///
/// Deliberately does **not** implement `std::error::Error` so that the
/// blanket `From<E: std::error::Error>` conversion below stays coherent
/// (the same trick `anyhow::Error` uses).
#[derive(Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Context`-style message attachment.
pub trait Context<T> {
    /// Replace/wrap the error with `msg: <original>`.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Lazily-built variant of [`Context::context`].
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_int(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("not an integer")?;
        ensure!(v >= 0, "negative: {v}");
        Ok(v)
    }

    #[test]
    fn conversions_and_context() {
        assert_eq!(parse_int("7").unwrap(), 7);
        let e = parse_int("abc").unwrap_err();
        assert!(e.to_string().starts_with("not an integer:"), "{e}");
        let e = parse_int("-3").unwrap_err();
        assert_eq!(e.to_string(), "negative: -3");
    }

    #[test]
    fn option_context_and_io_from() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(io().is_err());
    }
}
