//! Engine-level tests of the §4 initialization layer: FOM-seeded cold
//! solves must converge in no more generation rounds than
//! screening-seeded ones while reaching the same (≤ 1e-6 relative)
//! objective, on L1, Group and Slope instances; and the refactored
//! Backend-based FOM paths must be bit-identical at any thread count.

use cutgen::backend::NativeBackend;
use cutgen::coordinator::group::group_column_generation;
use cutgen::coordinator::l1svm::column_generation;
use cutgen::coordinator::slope::slope_column_constraint_generation;
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_group, generate_l1, GroupSpec, SyntheticSpec};
use cutgen::engine::{InitStrategy, Initializer};
use cutgen::fom::block_cd::{block_cd, BlockCdParams};
use cutgen::fom::fista::FistaParams;
use cutgen::fom::objective::bh_slope_weights;
use cutgen::rng::Xoshiro256;

/// An accurate-but-cheap FISTA configuration for the seeding FOM.
fn seed_fista() -> FistaParams {
    FistaParams { max_iters: 500, eta: 1e-6, ..Default::default() }
}

fn assert_fom_no_worse(
    label: &str,
    fom_rounds: usize,
    scr_rounds: usize,
    fom_obj: f64,
    scr_obj: f64,
) {
    assert!(
        (fom_obj - scr_obj).abs() / scr_obj.max(1e-9) <= 1e-6,
        "{label}: FOM-seeded objective {fom_obj} differs from screening-seeded {scr_obj}"
    );
    assert!(
        fom_rounds <= scr_rounds,
        "{label}: FOM seed needed MORE rounds ({fom_rounds}) than screening ({scr_rounds})"
    );
}

/// L1-SVM: a FISTA seed must not need more CG rounds than the
/// closed-form screening seed, at an identical optimum.
#[test]
fn l1_fom_seed_converges_in_no_more_rounds_than_screening() {
    let spec = SyntheticSpec { n: 60, p: 120, k0: 5, rho: 0.1, standardize: true };
    let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(401));
    let backend = NativeBackend::new(&ds.x);
    let lambda = 0.05 * ds.lambda_max_l1();
    // max_cols_per_round caps expansion so the round counts measure seed
    // quality; eps tight so both runs land on the true optimum
    let params = GenParams { eps: 1e-8, max_cols_per_round: 5, ..Default::default() };

    let scr = Initializer::new(InitStrategy::Screening, 10).seed_l1(&ds, &backend, lambda);
    let scr_sol = column_generation(&ds, &backend, lambda, &scr.ws.cols, &params);
    assert!(scr_sol.stats.converged);

    let fom = Initializer::new(InitStrategy::Fista, 10)
        .with_fom(seed_fista())
        .seed_l1(&ds, &backend, lambda);
    assert_eq!(fom.strategy, InitStrategy::Fista);
    let fom_sol = column_generation(&ds, &backend, lambda, &fom.ws.cols, &params);
    assert!(fom_sol.stats.converged);

    assert_fom_no_worse(
        "l1svm",
        fom_sol.stats.rounds,
        scr_sol.stats.rounds,
        fom_sol.objective,
        scr_sol.objective,
    );
}

/// Group-SVM: a block-CD seed must not need more CG rounds than
/// screening, at an identical optimum.
#[test]
fn group_fom_seed_converges_in_no_more_rounds_than_screening() {
    let spec = GroupSpec {
        n: 60,
        n_groups: 15,
        group_size: 4,
        k0_groups: 3,
        rho: 0.15,
        standardize: true,
    };
    let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(402));
    let ds = &gd.data;
    let backend = NativeBackend::new(&ds.x);
    let lambda = 0.08 * ds.lambda_max_group(&gd.groups);
    let params = GenParams { eps: 1e-8, max_cols_per_round: 2, ..Default::default() };

    let scr = Initializer::new(InitStrategy::Screening, 4).seed_group(ds, &gd.groups, lambda);
    let scr_sol = group_column_generation(ds, &backend, &gd.groups, lambda, &scr.ws.cols, &params);
    assert!(scr_sol.stats.converged);

    let fom = Initializer::new(InitStrategy::BlockCd, 4)
        .with_block_cd(BlockCdParams { max_sweeps: 300, tol: 1e-6, ..Default::default() })
        .seed_group(ds, &gd.groups, lambda);
    assert_eq!(fom.strategy, InitStrategy::BlockCd);
    let fom_sol = group_column_generation(ds, &backend, &gd.groups, lambda, &fom.ws.cols, &params);
    assert!(fom_sol.stats.converged);

    assert_fom_no_worse(
        "group",
        fom_sol.stats.rounds,
        scr_sol.stats.rounds,
        fom_sol.objective,
        scr_sol.objective,
    );
}

/// Slope-SVM: a FISTA (PAVA prox) seed must not need more generation
/// rounds than screening, at an identical optimum.
#[test]
fn slope_fom_seed_converges_in_no_more_rounds_than_screening() {
    let spec = SyntheticSpec { n: 40, p: 60, k0: 5, rho: 0.1, standardize: true };
    let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(403));
    let backend = NativeBackend::new(&ds.x);
    let weights = bh_slope_weights(ds.p(), 0.05 * ds.lambda_max_l1());
    let params =
        GenParams { eps: 1e-8, max_cols_per_round: 5, ..Default::default() };

    let scr = Initializer::new(InitStrategy::Screening, 10).seed_slope(&ds, &weights);
    let scr_sol =
        slope_column_constraint_generation(&ds, &backend, &weights, &scr.ws.cols, &params);
    assert!(scr_sol.stats.converged);

    let fom = Initializer::new(InitStrategy::Fista, 10)
        .with_fom(seed_fista())
        .seed_slope(&ds, &weights);
    let fom_sol =
        slope_column_constraint_generation(&ds, &backend, &weights, &fom.ws.cols, &params);
    assert!(fom_sol.stats.converged);

    assert_fom_no_worse(
        "slope",
        fom_sol.stats.rounds,
        scr_sol.stats.rounds,
        fom_sol.objective,
        scr_sol.objective,
    );
}

/// The refactored Backend-based block CD: threads 1 vs 4 produce
/// bit-identical coefficients, and the seeds built on top of them are
/// identical end to end (the satellite determinism guarantee).
#[test]
fn refactored_fom_paths_are_thread_identical_end_to_end() {
    let spec = GroupSpec {
        n: 50,
        n_groups: 12,
        group_size: 5,
        k0_groups: 3,
        rho: 0.2,
        standardize: true,
    };
    let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(404));
    let backend = NativeBackend::new(&gd.data.x);
    let lambda = 0.1 * gd.data.lambda_max_group(&gd.groups);

    // block_cd on the Backend trait, serial vs chunked group gradients
    let serial = block_cd(
        &backend,
        &gd.data.y,
        &gd.groups,
        lambda,
        &BlockCdParams { threads: 1, ..Default::default() },
        None,
    );
    let par = block_cd(
        &backend,
        &gd.data.y,
        &gd.groups,
        lambda,
        &BlockCdParams { threads: 4, ..Default::default() },
        None,
    );
    assert_eq!(serial.beta, par.beta, "block_cd must be thread-count independent");
    assert_eq!(serial.beta0, par.beta0);

    // the full seed path (screen → FOM → mass ranking) inherits it
    let mut a = Initializer::new(InitStrategy::BlockCd, 5);
    let mut b = a.clone();
    a.threads = 1;
    a.block_cd.threads = 1;
    b.threads = 4;
    b.block_cd.threads = 4;
    let sa = a.seed_group(&gd.data, &gd.groups, lambda);
    let sb = b.seed_group(&gd.data, &gd.groups, lambda);
    assert_eq!(sa.ws, sb.ws, "group seeds must be thread-count independent");
}
