//! End-to-end tests of the exact parametric λ-path: interpolated
//! objectives agree with independent fixed-λ solves everywhere on the
//! ridden range (l1svm, ranksvm, dantzig), the exact ride prices
//! strictly less than a dense warm-started grid, and the serve layer's
//! `path_exact` / `update` / `unregister` ops (breakpoint cache
//! seeding, snapshot translation to derived datasets, registry-level
//! eviction) behave over the line protocol.

use cutgen::backend::NativeBackend;
use cutgen::coordinator::path::{geometric_grid, regularization_path};
use cutgen::coordinator::path_exact::{
    dantzig_path_exact, l1svm_path_exact, ranksvm_path_exact,
};
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{
    generate_dantzig, generate_l1, generate_ranksvm, DantzigSpec, RankSpec, SyntheticSpec,
};
use cutgen::engine::PairMode;
use cutgen::rng::Xoshiro256;
use cutgen::serve::json::Json;
use cutgen::serve::ServeState;
use cutgen::workloads::dantzig::{dantzig_generation, lambda_max_dantzig};
use cutgen::workloads::pairset::{PairCosts, PairSet};
use cutgen::workloads::ranksvm::{
    lambda_max_rank, lambda_max_rank_weighted, ranksvm_generation, ranksvm_generation_costed,
};

fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-9)
}

fn tight_params() -> GenParams {
    GenParams { eps: 1e-8, seed_budget: 5, ..Default::default() }
}

/// The acceptance drive: ride the exact path over [½λ_max, λ_max],
/// then check it against a dense 50-point warm-started grid
/// (Algorithm 2) over the same range — every grid objective must match
/// the interpolated exact objective to ≤ 1e-6 relative, and the exact
/// ride must have priced the implicit column space strictly fewer
/// times than the grid did.
#[test]
fn l1svm_exact_path_matches_dense_warm_grid_with_fewer_pricing_rounds() {
    let spec = SyntheticSpec { n: 40, p: 80, k0: 5, rho: 0.1, standardize: true };
    let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(42));
    let backend = NativeBackend::new(&ds.x);
    let params = tight_params();
    let lmax = ds.lambda_max_l1();

    let path = l1svm_path_exact(&ds, &backend, lmax, 0.5 * lmax, &params);
    assert!(path.stats.breakpoints >= 2, "expected a ride, got {:?}", path.stats);
    assert!(!path.timed_out && !path.truncated);

    let ratio = 0.5f64.powf(1.0 / 49.0);
    let grid = geometric_grid(lmax, 50, ratio);
    let (grid_points, _) = regularization_path(&ds, &backend, &grid, &params);
    assert_eq!(grid_points.len(), 50);
    for pt in &grid_points {
        let interp = path
            .objective_at(pt.lambda)
            .unwrap_or_else(|| panic!("λ = {} not covered by the exact path", pt.lambda));
        assert!(
            rel_err(interp, pt.objective) <= 1e-6,
            "λ = {}: exact-interpolated {interp} vs grid {}",
            pt.lambda,
            pt.objective
        );
    }
    let grid_rounds = grid_points.last().unwrap().stats.rounds;
    assert!(
        path.stats.pricing_rounds < grid_rounds,
        "exact path must price strictly less: exact {} vs grid {}",
        path.stats.pricing_rounds,
        grid_rounds
    );
}

/// RankSVM: interpolated exact objectives match independent fixed-λ
/// solves on a dense grid inside the ridden range.
#[test]
fn ranksvm_exact_path_matches_direct_solves() {
    let spec = RankSpec { n: 24, p: 30, k0: 5, rho: 0.1, noise: 0.3, standardize: true };
    let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(7));
    let pairs = PairSet::build(&ds.y, PairMode::Auto);
    let backend = NativeBackend::new(&ds.x);
    let params = tight_params();
    let lmax = lambda_max_rank(&ds, &pairs);

    let path = ranksvm_path_exact(&ds, &backend, &pairs, lmax, 0.45 * lmax, &params);
    assert!(path.stats.breakpoints >= 2, "expected a ride, got {:?}", path.stats);
    for &lambda in &geometric_grid(lmax, 8, 0.9) {
        let direct = ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &params);
        let interp = path.objective_at(lambda).expect("λ inside the ridden range");
        assert!(
            rel_err(interp, direct.objective) <= 1e-6,
            "λ = {lambda}: exact-interpolated {interp} vs direct {}",
            direct.objective
        );
    }
}

/// Regression pin for the weighted-cost refactor: on this file's
/// RankSVM fixture, uniform costs (every gap 1, every weight 1) must
/// reproduce the pre-weighting solutions byte-identically — λ_max,
/// objective, β, and working sets, at every grid λ the exact-path test
/// above also visits.
#[test]
fn ranksvm_uniform_costs_pin_the_unweighted_fixture_bitwise() {
    let spec = RankSpec { n: 24, p: 30, k0: 5, rho: 0.1, noise: 0.3, standardize: true };
    let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(7));
    let pairs = PairSet::build(&ds.y, PairMode::Auto);
    let backend = NativeBackend::new(&ds.x);
    let params = tight_params();
    let lmax = lambda_max_rank(&ds, &pairs);
    assert_eq!(
        lmax.to_bits(),
        lambda_max_rank_weighted(&ds, &pairs, &PairCosts::UNIFORM).to_bits()
    );
    for &lambda in &geometric_grid(lmax, 8, 0.9) {
        let plain = ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &params);
        let costed = ranksvm_generation_costed(
            &ds,
            &backend,
            &pairs,
            &PairCosts::UNIFORM,
            lambda,
            &[],
            &[],
            &params,
        );
        assert_eq!(
            plain.objective.to_bits(),
            costed.objective.to_bits(),
            "objective drifted at λ = {lambda}"
        );
        for (j, (a, b)) in plain.beta.iter().zip(&costed.beta).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "β[{j}] drifted at λ = {lambda}");
        }
        assert_eq!(plain.cols, costed.cols, "column working set drifted at λ = {lambda}");
        assert_eq!(plain.rows, costed.rows, "pair working set drifted at λ = {lambda}");
        assert_eq!(costed.stats.pair_scan, Some("uniform"));
    }
}

/// Dantzig selector: same dense-grid agreement (RHS-parametric ride).
#[test]
fn dantzig_exact_path_matches_direct_solves() {
    let spec = DantzigSpec { n: 30, p: 40, k0: 5, rho: 0.1, sigma: 0.5, standardize: true };
    let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(13));
    let backend = NativeBackend::new(&ds.x);
    let params = tight_params();
    let lmax = lambda_max_dantzig(&ds);

    let path = dantzig_path_exact(&ds, &backend, lmax, 0.6 * lmax, &params);
    assert!(path.stats.breakpoints >= 1, "expected at least λ_max, got {:?}", path.stats);
    for &lambda in &geometric_grid(lmax, 6, 0.92) {
        let direct = dantzig_generation(&ds, &backend, lambda, &[], &params);
        let interp = path.objective_at(lambda).expect("λ inside the ridden range");
        assert!(
            rel_err(interp, direct.objective) <= 1e-6,
            "λ = {lambda}: exact-interpolated {interp} vs direct {}",
            direct.objective
        );
    }
}

/// Breakpoint geometry: λ's strictly decrease, segments tile the ridden
/// range without gaps, and endpoints carry the endpoint objectives.
#[test]
fn exact_path_segments_tile_the_range() {
    let spec = SyntheticSpec { n: 30, p: 60, k0: 5, rho: 0.1, standardize: true };
    let ds = generate_l1(&spec, &mut Xoshiro256::seed_from_u64(5));
    let backend = NativeBackend::new(&ds.x);
    let lmax = ds.lambda_max_l1();
    let path = l1svm_path_exact(&ds, &backend, lmax, 0.4 * lmax, &tight_params());
    assert_eq!(path.segments.len(), path.points.len() - 1);
    assert_eq!(path.points[0].support, 0, "λ_max starts with an empty model");
    for w in path.points.windows(2) {
        assert!(w[1].lambda < w[0].lambda, "λ must strictly decrease");
    }
    for (k, seg) in path.segments.iter().enumerate() {
        assert_eq!(seg.lambda_hi, path.points[k].lambda);
        assert_eq!(seg.lambda_lo, path.points[k + 1].lambda);
        assert_eq!(seg.obj_hi, path.points[k].objective);
        assert_eq!(seg.obj_lo, path.points[k + 1].objective);
    }
    // out-of-range λ's interpolate to nothing
    assert!(path.objective_at(2.0 * lmax).is_none());
    assert!(path.objective_at(0.01 * lmax).is_none());
}

// ---------------------------------------------------------------------------
// serve-layer ops
// ---------------------------------------------------------------------------

fn get_f64(v: &Json, key: &str) -> f64 {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_f64().unwrap()
}

fn get_usize(v: &Json, key: &str) -> usize {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_usize().unwrap()
}

fn get_bool(v: &Json, key: &str) -> bool {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_bool().unwrap()
}

fn assert_ok(v: &Json) {
    assert!(get_bool(v, "ok"), "request failed: {v}");
}

/// The `path_exact` op: breakpoints + segments come back over the
/// protocol, every breakpoint seeds the warm cache (so a later fixed-λ
/// solve at a breakpoint starts warm), and unsupported workloads are
/// refused with a pointer to the grid op.
#[test]
fn serve_path_exact_seeds_cache_at_every_breakpoint() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":11}}"#,
    ))
    .unwrap());
    let resp = Json::parse(&state.handle_line(
        r#"{"op":"path_exact","dataset":"d","workload":"l1svm","lambda_min_frac":0.4,"eps":1e-7}"#,
    ))
    .unwrap();
    assert_ok(&resp);
    let points = resp.get("points").unwrap().as_arr().unwrap();
    let segments = resp.get("segments").unwrap().as_arr().unwrap();
    assert_eq!(points.len(), get_usize(&resp, "breakpoints"));
    assert_eq!(segments.len(), points.len() - 1);
    assert!(points.len() >= 2, "expected a ride: {resp}");
    assert!(!get_bool(&resp, "timed_out"));
    assert_eq!(get_usize(&points[0], "support"), 0, "λ_max point has empty support");
    let seeded = get_usize(&resp, "cache_seeded");
    assert!(seeded >= 1, "breakpoints must seed the cache: {resp}");
    // a fixed-λ solve at the last breakpoint must start warm
    let last_lambda = get_f64(points.last().unwrap(), "lambda");
    let solve = Json::parse(&state.handle_line(&format!(
        r#"{{"op":"solve","dataset":"d","workload":"l1svm","lambda":{last_lambda},"eps":1e-7}}"#
    )))
    .unwrap();
    assert_ok(&solve);
    assert!(get_bool(&solve, "warm"), "breakpoint-seeded λ must hit the cache: {solve}");
    // the interpolated objective at the breakpoint matches the solve
    let so = get_f64(&solve, "objective");
    let po = get_f64(points.last().unwrap(), "objective");
    assert!(rel_err(po, so) <= 1e-6, "breakpoint {po} vs solve {so}");
    // group/slope have no parametric certificate: refused, grid suggested
    for wl in ["group", "slope"] {
        let bad = Json::parse(&state.handle_line(&format!(
            r#"{{"op":"path_exact","dataset":"d","workload":"{wl}"}}"#
        )))
        .unwrap();
        assert!(!get_bool(&bad, "ok"), "{wl} must be refused");
        let msg = bad.get("error").unwrap().as_str().unwrap();
        assert!(msg.contains("grid"), "error must point to the grid op: {msg}");
    }
    // malformed knobs and unknown datasets fail cleanly
    for bad in [
        r#"{"op":"path_exact","dataset":"ghost","workload":"l1svm"}"#,
        r#"{"op":"path_exact","dataset":"d","workload":"l1svm","lambda_min_frac":1.5}"#,
    ] {
        let resp = Json::parse(&state.handle_line(bad)).unwrap();
        assert!(!get_bool(&resp, "ok"), "{bad:?} should fail");
    }
}

/// The `update` op: derive a dataset from a registered parent (samples
/// retired, samples appended from another registered dataset), re-key
/// the parent's feature-indexed snapshots to the child, and re-solve
/// warm; `unregister` then drops the parent and purges its snapshots.
#[test]
fn serve_update_translates_snapshots_and_unregister_purges() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"parent","synthetic":{"kind":"l1","n":40,"p":80,"seed":11}}"#,
    ))
    .unwrap());
    // populate the parent's warm cache with one converged solve
    let cold = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"parent","workload":"l1svm","lambda_frac":0.05,"eps":1e-7}"#,
    ))
    .unwrap();
    assert_ok(&cold);
    let lambda = get_f64(&cold, "lambda");

    // retire three samples into a derived dataset
    let upd = Json::parse(&state.handle_line(
        r#"{"op":"update","dataset":"parent","name":"child","retire":[0,1,2]}"#,
    ))
    .unwrap();
    assert_ok(&upd);
    assert_eq!(get_usize(&upd, "n"), 37);
    assert_eq!(get_usize(&upd, "p"), 80);
    assert_eq!(get_usize(&upd, "retired"), 3);
    assert_eq!(get_usize(&upd, "appended"), 0);
    assert!(
        get_usize(&upd, "cache_translated") >= 1,
        "the parent's l1svm snapshot must translate: {upd}"
    );
    // the child's first solve at the parent's λ starts warm from the
    // translated snapshot (same absolute λ, so the bucket matches)
    let child = Json::parse(&state.handle_line(&format!(
        r#"{{"op":"solve","dataset":"child","workload":"l1svm","lambda":{lambda},"eps":1e-7}}"#
    )))
    .unwrap();
    assert_ok(&child);
    assert!(get_bool(&child, "warm"), "translated snapshot must warm the child: {child}");
    assert_eq!(child.get("seeded_by").unwrap().as_str(), Some("cache"));

    // append rows from another registered dataset (same p)
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"extra","synthetic":{"kind":"l1","n":10,"p":80,"seed":12}}"#,
    ))
    .unwrap());
    let grown = Json::parse(&state.handle_line(
        r#"{"op":"update","dataset":"child","name":"grown","append_from":{"dataset":"extra","rows":[0,1,2]}}"#,
    ))
    .unwrap();
    assert_ok(&grown);
    assert_eq!(get_usize(&grown, "n"), 40);
    assert_eq!(get_usize(&grown, "appended"), 3);

    // unregister the parent: bytes freed, snapshots purged, name gone
    let entries_before = {
        let stats = Json::parse(&state.handle_line(r#"{"op":"stats"}"#)).unwrap();
        get_usize(&stats, "cache_entries")
    };
    let un = Json::parse(&state.handle_line(r#"{"op":"unregister","name":"parent"}"#)).unwrap();
    assert_ok(&un);
    assert!(get_usize(&un, "freed_bytes") > 0);
    assert!(get_usize(&un, "cache_purged") >= 1, "parent snapshots must purge: {un}");
    let stats = Json::parse(&state.handle_line(r#"{"op":"stats"}"#)).unwrap();
    assert!(get_usize(&stats, "cache_entries") < entries_before);
    assert!(get_usize(&stats, "registry_bytes") > 0, "children remain registered");
    let gone = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"parent","workload":"l1svm"}"#,
    ))
    .unwrap();
    assert!(!get_bool(&gone, "ok"), "unregistered name must be unknown");

    // malformed updates fail cleanly
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"narrow","synthetic":{"kind":"l1","n":10,"p":20,"seed":1}}"#,
    ))
    .unwrap());
    for bad in [
        r#"{"op":"update","dataset":"child","name":"x"}"#,
        r#"{"op":"update","dataset":"child","name":"x","retire":[999]}"#,
        r#"{"op":"update","dataset":"child","name":"x","retire":"all"}"#,
        r#"{"op":"update","dataset":"ghost","name":"x","retire":[0]}"#,
        r#"{"op":"update","dataset":"child","name":"x","append_from":{"dataset":"narrow"}}"#,
        r#"{"op":"unregister","name":"ghost"}"#,
    ] {
        let resp = Json::parse(&state.handle_line(bad)).unwrap();
        assert!(!get_bool(&resp, "ok"), "{bad:?} should fail");
    }
}

/// `--registry-bytes`: registering past the budget evicts the
/// least-recently-used dataset exactly like an `unregister` — name
/// dropped, snapshots purged — and `stats` counts the eviction.
#[test]
fn serve_registry_byte_budget_evicts_lru_dataset() {
    // one 40×80 dense design ≈ 25.6 KB + responses; budget fits one
    let state = ServeState::new(64).with_registry_bytes(30_000);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"a","synthetic":{"kind":"l1","n":40,"p":80,"seed":1}}"#,
    ))
    .unwrap());
    // seed a's warm cache so the eviction has snapshots to purge
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"a","workload":"l1svm","lambda_frac":0.05}"#,
    ))
    .unwrap());
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"b","synthetic":{"kind":"l1","n":40,"p":80,"seed":2}}"#,
    ))
    .unwrap());
    let stats = Json::parse(&state.handle_line(r#"{"op":"stats"}"#)).unwrap();
    assert_eq!(get_usize(&stats, "registry_evictions"), 1, "a must be evicted: {stats}");
    assert_eq!(get_usize(&stats, "cache_entries"), 0, "a's snapshots must purge: {stats}");
    let datasets = stats.get("datasets").unwrap().as_arr().unwrap();
    assert_eq!(datasets.len(), 1);
    assert_eq!(datasets[0].get("name").unwrap().as_str(), Some("b"));
    let gone =
        Json::parse(&state.handle_line(r#"{"op":"solve","dataset":"a","workload":"l1svm"}"#))
            .unwrap();
    assert!(!get_bool(&gone, "ok"), "evicted dataset must be unknown");
    // the kept dataset still serves
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"b","workload":"l1svm","lambda_frac":0.05}"#,
    ))
    .unwrap());
}
