//! Cross-module integration tests: exercise the public API the way a
//! downstream user would — data generation → first-order init → cutting
//! planes → solution checks — plus cross-method agreement and failure
//! injection.

use cutgen::backend::{Backend, NativeBackend};
use cutgen::baselines::admm::{admm_l1svm, AdmmParams};
use cutgen::baselines::dantzig_full::solve_full_dantzig;
use cutgen::baselines::full_lp::{solve_full_group, solve_full_l1};
use cutgen::baselines::psm::psm_l1svm;
use cutgen::baselines::ranksvm_full::solve_full_ranksvm;
use cutgen::baselines::slope_full::solve_slope_full;
use cutgen::coordinator::group::{group_column_generation, initial_groups};
use cutgen::coordinator::l1svm::{column_generation, constraint_generation};
use cutgen::coordinator::slope::slope_column_constraint_generation;
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{
    generate_dantzig, generate_group, generate_l1, generate_ranksvm, generate_sparse_text,
    DantzigSpec, GroupSpec, RankSpec, SparseTextSpec, SyntheticSpec,
};
use cutgen::data::{libsvm, Dataset};
use cutgen::engine::PairMode;
use cutgen::fom::fista::{fista, FistaParams, Penalty};
use cutgen::fom::objective::{bh_slope_weights, l1_objective};
use cutgen::rng::Xoshiro256;
use cutgen::workloads::dantzig::{dantzig_generation, lambda_max_dantzig};
use cutgen::workloads::pairset::PairSet;
use cutgen::workloads::ranksvm::{lambda_max_rank, ranksvm_generation};

fn synth(n: usize, p: usize, seed: u64) -> Dataset {
    generate_l1(&SyntheticSpec::paper_default(n, p), &mut Xoshiro256::seed_from_u64(seed))
}

/// Every solver in the repo must agree on the L1-SVM optimum.
#[test]
fn all_l1_methods_agree_on_objective() {
    let ds = synth(40, 60, 1);
    let lambda = 0.05 * ds.lambda_max_l1();
    let backend = NativeBackend::new(&ds.x);
    let tight = GenParams { eps: 1e-7, ..Default::default() };

    let full = solve_full_l1(&ds, lambda).objective;
    let cg = column_generation(&ds, &backend, lambda, &[0], &tight).objective;
    let cng = constraint_generation(&ds, lambda, &[0, 1, 2], &tight).objective;
    let psm = psm_l1svm(&ds, lambda).solution.objective;
    let admm = {
        let r = admm_l1svm(
            &backend,
            &ds.y,
            lambda,
            &AdmmParams { max_iters: 10_000, tol: 1e-8, ..Default::default() },
        );
        l1_objective(&backend, &ds.y, &r.beta, r.beta0, lambda)
    };
    let fo = {
        let r = fista(
            &backend,
            &ds.y,
            &Penalty::L1(lambda),
            &FistaParams { max_iters: 4000, eta: 1e-10, tau: 0.05, ..Default::default() },
            None,
        );
        l1_objective(&backend, &ds.y, &r.beta, r.beta0, lambda)
    };

    let rel = |a: f64| (a - full).abs() / full;
    assert!(rel(cg) < 1e-5, "cg {cg} vs full {full}");
    assert!(rel(cng) < 1e-5, "cng {cng} vs full {full}");
    assert!(rel(psm) < 1e-5, "psm {psm} vs full {full}");
    // first-order methods are approximate but must be close from above
    assert!(admm >= full - 1e-7 && rel(admm) < 0.03, "admm {admm} vs {full}");
    assert!(fo >= full - 1e-7 && rel(fo) < 0.08, "fista {fo} vs {full}"); // FOM = low accuracy by design (§4)
}

/// Sparse and dense storage must produce identical coordinators' output.
#[test]
fn sparse_dense_coordinator_parity() {
    // build a dataset, write libsvm, reload (sparse), compare solutions
    let ds_dense = synth(30, 40, 2);
    let path = std::env::temp_dir().join("cutgen_integration_parity.svm");
    libsvm::write_file(&ds_dense, &path).unwrap();
    let ds_sparse = libsvm::read_file(&path, ds_dense.p()).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(ds_sparse.x.is_sparse());

    let lambda = 0.05 * ds_dense.lambda_max_l1();
    let tight = GenParams { eps: 1e-7, ..Default::default() };
    let bd = NativeBackend::new(&ds_dense.x);
    let bs = NativeBackend::new(&ds_sparse.x);
    let a = column_generation(&ds_dense, &bd, lambda, &[0], &tight);
    let b = column_generation(&ds_sparse, &bs, lambda, &[0], &tight);
    assert!(
        (a.objective - b.objective).abs() / a.objective < 1e-6,
        "dense {} sparse {}",
        a.objective,
        b.objective
    );
}

/// The ε guarantee: a CG solution's true suboptimality is bounded by the
/// pricing slack — ε·(number of columns) is a crude but valid bound; we
/// check the much stronger empirical property rel-gap ≤ ε.
#[test]
fn eps_controls_suboptimality() {
    let ds = synth(50, 120, 3);
    let lambda = 0.03 * ds.lambda_max_l1();
    let backend = NativeBackend::new(&ds.x);
    let exact = solve_full_l1(&ds, lambda).objective;
    for eps in [0.5, 0.1, 0.01] {
        let sol = column_generation(
            &ds,
            &backend,
            lambda,
            &[0],
            &GenParams { eps, ..Default::default() },
        );
        let gap = (sol.objective - exact) / exact;
        assert!(gap >= -1e-7, "cannot beat the optimum");
        assert!(gap <= eps, "eps {eps}: gap {gap}");
    }
}

/// Failure injection: degenerate datasets must not break the pipeline.
#[test]
fn degenerate_inputs_are_handled() {
    // (a) all labels equal → LP still solves (β=0, β₀ = +1 side)
    let mut ds = synth(20, 10, 4);
    ds.y = vec![1.0; 20];
    let backend = NativeBackend::new(&ds.x);
    let sol = column_generation(&ds, &backend, 1.0, &[0], &GenParams::default());
    assert!(sol.objective <= 1e-6, "separable by intercept: {}", sol.objective);

    // (b) duplicated features → CG must still terminate
    let base = generate_l1(
        &SyntheticSpec { n: 20, p: 5, k0: 3, rho: 0.1, standardize: true },
        &mut Xoshiro256::seed_from_u64(5),
    );
    let mut cols = Vec::new();
    for rep in 0..4 {
        let _ = rep;
        for j in 0..5 {
            cols.push(base.x.col_entries(j));
        }
    }
    let mut coo = cutgen::sparse::Coo::new(20, 20);
    for (j, entries) in cols.iter().enumerate() {
        for &(i, v) in entries {
            coo.push(i, j, v);
        }
    }
    let dup = Dataset { x: cutgen::data::Design::sparse(coo.to_csr()), y: base.y.clone() };
    let backend = NativeBackend::new(&dup.x);
    let lambda = 0.05 * dup.lambda_max_l1();
    let sol = column_generation(&dup, &backend, lambda, &[0], &GenParams::default());
    assert!(sol.objective.is_finite());

    // (c) a feature that is identically zero
    let mut coo = cutgen::sparse::Coo::new(10, 3);
    for i in 0..10 {
        coo.push(i, 0, 1.0);
        coo.push(i, 1, if i % 2 == 0 { 1.0 } else { -1.0 });
        // column 2 stays empty
    }
    let y: Vec<f64> = (0..10).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let zed = Dataset { x: cutgen::data::Design::sparse(coo.to_csr()), y };
    let backend = NativeBackend::new(&zed.x);
    let sol = column_generation(&zed, &backend, 0.1, &[2], &GenParams::default());
    assert!(sol.objective.is_finite());
}

/// Prediction consistency: the fitted classifier must separate a strongly
/// signalled dataset almost perfectly in-sample.
#[test]
fn classifier_predicts_training_data() {
    let ds = synth(80, 50, 6);
    let backend = NativeBackend::new(&ds.x);
    let lambda = 0.01 * ds.lambda_max_l1();
    let sol = column_generation(&ds, &backend, lambda, &[0, 1], &GenParams::default());
    let mut correct = 0;
    for i in 0..ds.n() {
        let xi: Vec<f64> = (0..ds.p()).map(|j| ds.x.get(i, j)).collect();
        if sol.predict(&xi) == ds.y[i] {
            correct += 1;
        }
    }
    assert!(correct as f64 >= 0.95 * ds.n() as f64, "{correct}/{}", ds.n());
}

/// Sparse text workloads run the whole hybrid pipeline.
#[test]
fn sparse_hybrid_pipeline_runs() {
    let spec = SparseTextSpec { n: 600, p: 1500, density: 0.01, k0: 25, zipf: 1.1 };
    let ds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(7));
    let lambda = 0.05 * ds.lambda_max_l1();
    let (sol, split) = cutgen::exps::common::sfo_cl_cng(&ds, lambda, 1e-2, 100, 9);
    assert!(sol.objective.is_finite());
    assert!(split.total() > 0.0);
    assert!(sol.rows.len() <= ds.n());
    assert!(sol.cols.len() < ds.p());
}

/// Group-SVM through the engine-based coordinator must match the full LP
/// (every group in the model) at tight ε.
#[test]
fn group_engine_matches_full_lp() {
    let spec = GroupSpec {
        n: 45,
        n_groups: 18,
        group_size: 4,
        k0_groups: 3,
        rho: 0.15,
        standardize: true,
    };
    let gd = generate_group(&spec, &mut Xoshiro256::seed_from_u64(51));
    let lambda = 0.08 * gd.data.lambda_max_group(&gd.groups);
    let full = solve_full_group(&gd.data, &gd.groups, lambda).objective;
    let backend = NativeBackend::new(&gd.data.x);
    let init = initial_groups(&gd.data, &gd.groups, 2);
    let sol = group_column_generation(
        &gd.data,
        &backend,
        &gd.groups,
        lambda,
        &init,
        &GenParams { eps: 1e-7, ..Default::default() },
    );
    assert!(
        (sol.objective - full).abs() / full.max(1e-9) < 1e-5,
        "engine {} full {}",
        sol.objective,
        full
    );
    assert!(sol.cols.len() <= gd.groups.len());
}

/// Slope-SVM through the engine-based coordinator must match the
/// independent A.2 sum-of-top-m reformulation at tight ε.
#[test]
fn slope_engine_matches_full_reformulation() {
    let ds = synth(25, 12, 52);
    let lambda = bh_slope_weights(12, 0.05 * ds.lambda_max_l1());
    let full = solve_slope_full(&ds, &lambda)
        .expect("reformulation within row budget")
        .objective;
    let backend = NativeBackend::new(&ds.x);
    let sol = slope_column_constraint_generation(
        &ds,
        &backend,
        &lambda,
        &[0, 1],
        &GenParams { eps: 1e-7, ..Default::default() },
    );
    assert!(
        (sol.objective - full).abs() / full.max(1e-9) < 1e-4,
        "engine {} reformulation {}",
        sol.objective,
        full
    );
}

/// Parallel pricing must be a pure speed knob: identical working sets and
/// objectives at 1 and 4 threads, on dense and sparse data.
#[test]
fn parallel_pricing_produces_identical_working_sets() {
    let dense = synth(60, 250, 53);
    let sparse = generate_sparse_text(
        &SparseTextSpec { n: 200, p: 600, density: 0.02, k0: 20, zipf: 1.1 },
        &mut Xoshiro256::seed_from_u64(54),
    );
    for ds in [&dense, &sparse] {
        let lambda = 0.04 * ds.lambda_max_l1();
        let backend = NativeBackend::new(&ds.x);
        let serial = column_generation(
            ds,
            &backend,
            lambda,
            &[0],
            &GenParams { eps: 1e-6, threads: 1, ..Default::default() },
        );
        let parallel = column_generation(
            ds,
            &backend,
            lambda,
            &[0],
            &GenParams { eps: 1e-6, threads: 4, ..Default::default() },
        );
        assert_eq!(serial.cols, parallel.cols, "working set J must be identical");
        assert_eq!(serial.rows, parallel.rows, "working set I must be identical");
        assert_eq!(
            serial.stats.rounds, parallel.stats.rounds,
            "generation trajectory must be identical"
        );
        assert_eq!(serial.objective, parallel.objective);
    }
}

/// RankSVM through the engine must match the independent full pairwise
/// LP (every comparison pair materialized) to ≤1e-6 relative objective
/// gap at tight ε.
#[test]
fn ranksvm_engine_matches_full_pairwise_lp() {
    let spec = RankSpec { n: 22, p: 25, k0: 5, rho: 0.1, noise: 0.3, standardize: true };
    let ds = generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(61));
    let full_pairs = cutgen::workloads::ranksvm::ranking_pairs(&ds.y);
    let backend = NativeBackend::new(&ds.x);
    // BOTH pair-channel representations must match the independent
    // full pairwise LP — the implicit sweep is no approximation
    for mode in [PairMode::Enumerate, PairMode::Implicit] {
        let pairs = PairSet::build(&ds.y, mode);
        let lambda = 0.05 * lambda_max_rank(&ds, &pairs);
        let full = solve_full_ranksvm(&ds, &full_pairs, lambda).objective;
        let sol = ranksvm_generation(
            &ds,
            &backend,
            &pairs,
            lambda,
            &[],
            &[],
            &GenParams { eps: 1e-9, ..Default::default() },
        );
        assert!(
            (sol.objective - full).abs() / full.max(1e-9) <= 1e-6,
            "{}: engine {} full {}",
            pairs.mode(),
            sol.objective,
            full
        );
        assert!(
            sol.rows.len() < pairs.len(),
            "{}: only {} of {} pairs should be materialized",
            pairs.mode(),
            sol.rows.len(),
            pairs.len()
        );
    }
}

/// Dantzig selector through the engine must match the independent full
/// LP (all p correlation rows, explicit Gram) to ≤1e-6 relative gap.
#[test]
fn dantzig_engine_matches_full_lp() {
    let spec = DantzigSpec { n: 35, p: 30, k0: 5, rho: 0.1, sigma: 0.4, standardize: true };
    let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(62));
    let lambda = 0.3 * lambda_max_dantzig(&ds);
    let full = solve_full_dantzig(&ds, lambda).objective;
    let backend = NativeBackend::new(&ds.x);
    let sol = dantzig_generation(
        &ds,
        &backend,
        lambda,
        &[],
        &GenParams { eps: 1e-9, ..Default::default() },
    );
    assert!(
        (sol.objective - full).abs() / full.max(1e-9) <= 1e-6,
        "engine {} full {}",
        sol.objective,
        full
    );
}

/// The thread knob stays a pure speed knob on the new workloads too:
/// identical working sets and objectives at 1 and 4 pricing threads.
#[test]
fn workload_parallel_pricing_identical() {
    let spec = DantzigSpec { n: 30, p: 80, k0: 6, rho: 0.1, sigma: 0.4, standardize: true };
    let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(63));
    let lambda = 0.25 * lambda_max_dantzig(&ds);
    let backend = NativeBackend::new(&ds.x);
    let serial = dantzig_generation(
        &ds,
        &backend,
        lambda,
        &[],
        &GenParams { eps: 1e-7, threads: 1, ..Default::default() },
    );
    let parallel = dantzig_generation(
        &ds,
        &backend,
        lambda,
        &[],
        &GenParams { eps: 1e-7, threads: 4, ..Default::default() },
    );
    assert_eq!(serial.cols, parallel.cols, "working set J must be identical");
    assert_eq!(serial.rows, parallel.rows, "working set I must be identical");
    assert_eq!(serial.objective, parallel.objective);

    let rspec = RankSpec { n: 25, p: 60, k0: 5, rho: 0.1, noise: 0.3, standardize: true };
    let rds = generate_ranksvm(&rspec, &mut Xoshiro256::seed_from_u64(64));
    let pairs = PairSet::build(&rds.y, PairMode::Auto);
    let rlam = 0.05 * lambda_max_rank(&rds, &pairs);
    let rbackend = NativeBackend::new(&rds.x);
    let a = ranksvm_generation(
        &rds,
        &rbackend,
        &pairs,
        rlam,
        &[],
        &[],
        &GenParams { eps: 1e-7, threads: 1, ..Default::default() },
    );
    let b = ranksvm_generation(
        &rds,
        &rbackend,
        &pairs,
        rlam,
        &[],
        &[],
        &GenParams { eps: 1e-7, threads: 4, ..Default::default() },
    );
    assert_eq!(a.cols, b.cols);
    assert_eq!(a.rows, b.rows);
    assert_eq!(a.objective, b.objective);
}

/// PJRT backend (when artifacts exist) must drive column generation to
/// the same answer as the native backend.
#[test]
fn pjrt_coordinator_parity() {
    use cutgen::runtime::{PjrtBackend, PjrtRuntime};
    if !PjrtRuntime::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = PjrtRuntime::load(PjrtRuntime::default_dir()).unwrap();
    let ds = synth(60, 300, 8);
    let lambda = 0.02 * ds.lambda_max_l1();
    let tight = GenParams { eps: 1e-6, ..Default::default() };
    let native = NativeBackend::new(&ds.x);
    let pjrt = PjrtBackend::new(&rt, &ds.x).unwrap();
    assert_eq!(pjrt.name(), "pjrt");
    let a = column_generation(&ds, &native, lambda, &[0], &tight);
    let b = column_generation(&ds, &pjrt, lambda, &[0], &tight);
    assert!(
        (a.objective - b.objective).abs() / a.objective < 1e-4,
        "native {} pjrt {}",
        a.objective,
        b.objective
    );
}
