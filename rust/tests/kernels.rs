//! Cross-layout kernel contracts: the dense (register-tiled) and sparse
//! (CSR/CSC) implementations of every Backend kernel must agree to
//! 1e-12 on the same matrix, and the chunked sparse pricing must be
//! bit-identical at any thread count. See docs/kernels.md for why the
//! cross-layout bound is a tolerance while the thread bound is exact.

use cutgen::backend::{par_col_dots, par_xtv, Backend, NativeBackend};
use cutgen::data::synthetic::{generate_sparse_text, SparseTextSpec};
use cutgen::data::{Dataset, Design};
use cutgen::rng::Xoshiro256;
use cutgen::sparse::Coo;

const TOL: f64 = 1e-12;

/// Rebuild the same matrix in the other layout.
fn dense_twin(x: &Design) -> Design {
    match x {
        Design::Sparse { csr, .. } => Design::Dense(csr.to_dense()),
        Design::Dense(_) => panic!("expected a sparse design"),
    }
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

/// Assert every Backend kernel agrees across the two layouts of the
/// same matrix: `xtv`, `xtv_range` at several splits, `xb`, `col_dot`
/// on every column, and `col_axpy`.
fn assert_layouts_agree(sparse: &Design, label: &str) {
    let dense = dense_twin(sparse);
    let (n, p) = (sparse.rows(), sparse.cols());
    let sb = NativeBackend::new(sparse);
    let db = NativeBackend::new(&dense);
    assert!(sb.supports_range_pricing(), "{label}: sparse backend must support range pricing");

    let mut rng = Xoshiro256::seed_from_u64(0xC0FFEE);
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let beta: Vec<f64> = (0..p).map(|_| rng.normal() * 0.1).collect();

    // xtv
    let mut qs = vec![0.0; p];
    let mut qd = vec![0.0; p];
    sb.xtv(&v, &mut qs);
    db.xtv(&v, &mut qd);
    assert!(max_abs_diff(&qs, &qd) <= TOL, "{label}: xtv disagrees across layouts");

    // xtv_range at a handful of splits, reassembled
    for j0 in [0, 1, p / 3, p / 2, p.saturating_sub(1)] {
        let w = p - j0;
        let mut rs = vec![0.0; w];
        let mut rd = vec![0.0; w];
        sb.xtv_range(&v, j0, &mut rs);
        db.xtv_range(&v, j0, &mut rd);
        assert!(
            max_abs_diff(&rs, &rd) <= TOL,
            "{label}: xtv_range(j0={j0}) disagrees across layouts"
        );
        assert!(
            max_abs_diff(&rs, &qs[j0..]) <= TOL,
            "{label}: sparse xtv_range(j0={j0}) disagrees with full xtv"
        );
    }

    // xb
    let mut ms = vec![0.0; n];
    let mut md = vec![0.0; n];
    sb.xb(&beta, &mut ms);
    db.xb(&beta, &mut md);
    assert!(max_abs_diff(&ms, &md) <= TOL, "{label}: xb disagrees across layouts");

    // col_dot on every column (empty columns must give exactly 0 both ways)
    for j in 0..p {
        let (a, b) = (sb.col_dot(j, &v), db.col_dot(j, &v));
        assert!((a - b).abs() <= TOL, "{label}: col_dot({j}) disagrees: {a} vs {b}");
    }

    // col_axpy scattered into the same accumulator
    let mut outs = vec![0.0; n];
    let mut outd = vec![0.0; n];
    for j in (0..p).step_by((p / 7).max(1)) {
        sb.col_axpy(j, 0.5 + j as f64 * 1e-3, &mut outs);
        db.col_axpy(j, 0.5 + j as f64 * 1e-3, &mut outd);
    }
    assert!(max_abs_diff(&outs, &outd) <= TOL, "{label}: col_axpy disagrees across layouts");
}

/// Random power-law text design — the Table 3 regime.
#[test]
fn kernels_agree_on_power_law_design() {
    let spec = SparseTextSpec { n: 300, p: 900, density: 0.02, k0: 10, zipf: 1.1 };
    let ds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(11));
    assert!(ds.x.is_sparse());
    assert_layouts_agree(&ds.x, "power-law");
}

/// Adversarial: empty columns, empty rows, and a dense-ish stripe.
#[test]
fn kernels_agree_with_empty_columns_and_rows() {
    let (n, p) = (40, 60);
    let mut coo = Coo::new(n, p);
    let mut rng = Xoshiro256::seed_from_u64(17);
    for j in 0..p {
        // every third column left completely empty
        if j % 3 == 2 {
            continue;
        }
        // rows 10..20 never touched (empty rows in CSR)
        for i in (0..n).filter(|&i| !(10..20).contains(&i)).step_by(1 + j % 5) {
            coo.push(i, j, rng.normal());
        }
    }
    assert_layouts_agree(&Design::sparse(coo.to_csr()), "empty-cols-rows");
}

/// Adversarial: exactly one stored entry per (non-empty) column.
#[test]
fn kernels_agree_on_single_nnz_columns() {
    let (n, p) = (50, 80);
    let mut coo = Coo::new(n, p);
    let mut rng = Xoshiro256::seed_from_u64(23);
    for j in 0..p {
        if j % 7 == 6 {
            continue; // a few empty columns among the singletons
        }
        coo.push((j * 13) % n, j, rng.normal() * 2.0);
    }
    assert_layouts_agree(&Design::sparse(coo.to_csr()), "single-nnz");
}

/// The determinism contract: nnz-balanced chunked sparse pricing is
/// *bitwise* identical across thread counts (not merely within 1e-12).
/// The spec keeps nnz above the PAR_MIN_WORK spawn gate so the threaded
/// path really runs.
#[test]
fn sparse_pricing_thread_counts_bit_identical() {
    let spec = SparseTextSpec { n: 2000, p: 2000, density: 0.02, k0: 20, zipf: 1.1 };
    let ds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(31));
    assert!(ds.x.nnz() >= 1 << 15, "spec must exceed the spawn gate (nnz = {})", ds.x.nnz());
    let backend = NativeBackend::new(&ds.x);
    let mut rng = Xoshiro256::seed_from_u64(32);
    let v: Vec<f64> = (0..ds.n()).map(|_| rng.normal()).collect();

    let mut base = vec![0.0; ds.p()];
    par_xtv(&backend, 1, &v, &mut base);
    for t in [2usize, 4] {
        let mut out = vec![0.0; ds.p()];
        par_xtv(&backend, t, &v, &mut out);
        assert_eq!(base, out, "par_xtv not bit-identical at {t} threads");
    }

    let cols: Vec<usize> = (0..ds.p()).step_by(2).collect();
    let serial = par_col_dots(&backend, 1, &cols, &v);
    for t in [2usize, 4] {
        assert_eq!(
            serial,
            par_col_dots(&backend, t, &cols, &v),
            "par_col_dots not bit-identical at {t} threads"
        );
    }
}

/// End-to-end: column generation run on the sparse design and on its
/// dense twin selects the same support and reaches the same objective.
#[test]
fn engine_working_set_identical_dense_vs_sparse() {
    use cutgen::coordinator::l1svm::column_generation;
    use cutgen::coordinator::GenParams;

    let spec = SparseTextSpec { n: 120, p: 500, density: 0.03, k0: 8, zipf: 1.1 };
    let sds = generate_sparse_text(&spec, &mut Xoshiro256::seed_from_u64(41));
    let dds = Dataset { x: dense_twin(&sds.x), y: sds.y.clone() };

    let lam = 0.05 * sds.lambda_max_l1();
    let params = GenParams::default();
    let sb = NativeBackend::new(&sds.x);
    let db = NativeBackend::new(&dds.x);
    let ssol = column_generation(&sds, &sb, lam, &[0, 1], &params);
    let dsol = column_generation(&dds, &db, lam, &[0, 1], &params);

    let support = |beta: &[f64]| -> Vec<usize> {
        beta.iter()
            .enumerate()
            .filter(|(_, b)| b.abs() > 1e-9)
            .map(|(j, _)| j)
            .collect()
    };
    assert_eq!(
        support(&ssol.beta),
        support(&dsol.beta),
        "dense and sparse solves selected different supports"
    );
    let rel = (ssol.objective - dsol.objective).abs() / dsol.objective.abs().max(1.0);
    assert!(rel <= 1e-9, "objectives diverged: {} vs {}", ssol.objective, dsol.objective);
}
