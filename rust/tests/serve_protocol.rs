//! End-to-end tests of the solve service: the full line-delimited JSON
//! protocol over the stdin-style transport, warm-start cache semantics
//! on every workload, grid and batch endpoints across all five
//! workloads, snapshot persistence across a restart,
//! serial-vs-concurrent consistency, snapshot export/import, and the
//! TCP transport.

use std::io::Cursor;

use cutgen::backend::NativeBackend;
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_dantzig, DantzigSpec};
use cutgen::engine::{BackendPricer, GenEngine, Snapshot};
use cutgen::rng::Xoshiro256;
use cutgen::serve::json::Json;
use cutgen::serve::transport::{client_send, client_send_many, serve_lines, serve_tcp};
use cutgen::serve::ServeState;
use cutgen::workloads::dantzig::{
    dantzig_generation, initial_features, lambda_max_dantzig, DantzigProblem, RestrictedDantzig,
};

fn run_script(state: &ServeState, script: &str) -> Vec<Json> {
    let mut out: Vec<u8> = Vec::new();
    serve_lines(state, Cursor::new(script.as_bytes()), &mut out).unwrap();
    let text = std::str::from_utf8(&out).unwrap();
    text.lines().map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e}"))).collect()
}

fn get_f64(v: &Json, key: &str) -> f64 {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_f64().unwrap()
}

fn get_usize(v: &Json, key: &str) -> usize {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_usize().unwrap()
}

fn get_bool(v: &Json, key: &str) -> bool {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_bool().unwrap()
}

fn assert_ok(v: &Json) {
    assert!(get_bool(v, "ok"), "request failed: {v}");
}

/// The acceptance-criteria drive: over the stdin transport, register a
/// dataset, solve cold, re-solve a nearby λ with a cache hit, and check
/// the warm solve uses strictly fewer generation rounds while matching
/// the cold objective to ≤ 1e-6 relative. The requests pin
/// `"init":"screening"` so the cold round counts measure the cache, not
/// the (default) first-order seeding.
#[test]
fn stdin_transport_warm_start_end_to_end() {
    let state = ServeState::new(64);
    // max_cols_per_round caps expansion so round counts reflect how far
    // from the optimum each solve started
    let script = concat!(
        r#"{"op":"register","name":"d1","synthetic":{"kind":"l1","n":60,"p":200,"seed":7}}"#,
        "\n",
        r#"{"op":"solve","dataset":"d1","workload":"l1svm","lambda_frac":0.02,"eps":1e-6,"max_cols_per_round":5,"init":"screening"}"#,
        "\n",
        r#"{"op":"solve","dataset":"d1","workload":"l1svm","lambda_frac":0.018,"eps":1e-6,"max_cols_per_round":5,"init":"screening"}"#,
        "\n",
        r#"{"op":"solve","dataset":"d1","workload":"l1svm","lambda_frac":0.018,"eps":1e-6,"max_cols_per_round":5,"cache":false,"init":"screening"}"#,
        "\n",
        r#"{"op":"stats"}"#,
        "\n",
    );
    let resp = run_script(&state, script);
    assert_eq!(resp.len(), 5);
    for r in &resp {
        assert_ok(r);
    }
    let (reg, cold1, warm, cold2, stats) =
        (&resp[0], &resp[1], &resp[2], &resp[3], &resp[4]);
    assert_eq!(get_usize(reg, "n"), 60);
    assert_eq!(get_usize(reg, "p"), 200);

    assert!(!get_bool(cold1, "warm"), "first solve must be cold");
    assert!(get_bool(cold1, "converged"));

    // nearby λ: the cache must hit and resume from the snapshot
    assert!(get_bool(warm, "warm"), "nearby λ must hit the cache: {warm}");
    assert!(get_bool(warm, "converged"));
    assert!(!get_bool(cold2, "warm"), "cache:false must solve cold");

    // fewer generation rounds warm than cold, same optimum
    let warm_rounds = get_usize(warm, "rounds");
    let cold_rounds = get_usize(cold2, "rounds");
    assert!(
        warm_rounds < cold_rounds,
        "warm start must save rounds: warm {warm_rounds}, cold {cold_rounds}"
    );
    let wo = get_f64(warm, "objective");
    let co = get_f64(cold2, "objective");
    assert!(
        (wo - co).abs() / co.max(1e-9) <= 1e-6,
        "warm {wo} vs cold {co} at the same λ"
    );

    assert!(get_usize(stats, "cache_hits") >= 1, "stats must report the hit: {stats}");
    assert_eq!(get_usize(stats, "requests"), 5);
}

/// Cache correctness on every workload: a warm-started solve from a
/// snapshot matches a cold solve of the same request to ≤ 1e-6 relative
/// objective, without using more rounds.
#[test]
fn warm_solve_matches_cold_on_every_workload() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":11}}"#,
    ))
    .unwrap());
    for (workload, frac) in [
        ("l1svm", 0.05),
        ("group", 0.1),
        ("slope", 0.05),
        ("ranksvm", 0.05),
        ("dantzig", 0.3),
    ] {
        let req = format!(
            r#"{{"op":"solve","dataset":"d","workload":"{workload}","lambda_frac":{frac},"eps":1e-7}}"#
        );
        let cold = Json::parse(&state.handle_line(&req)).unwrap();
        assert_ok(&cold);
        assert!(!get_bool(&cold, "warm"), "{workload}: first solve must be cold");
        let warm = Json::parse(&state.handle_line(&req)).unwrap();
        assert_ok(&warm);
        assert!(get_bool(&warm, "warm"), "{workload}: repeat must hit the cache");
        assert_ne!(
            cold.get("seeded_by").unwrap().as_str(),
            Some("cache"),
            "{workload}: cold must report its resolved init strategy"
        );
        assert_eq!(
            warm.get("seeded_by").unwrap().as_str(),
            Some("cache"),
            "{workload}: warm must report the cache seed"
        );
        let co = get_f64(&cold, "objective");
        let wo = get_f64(&warm, "objective");
        assert!(
            (wo - co).abs() / co.max(1e-9) <= 1e-6,
            "{workload}: warm {wo} vs cold {co}"
        );
        // Slope's epigraph cuts regenerate from incumbents, so its warm
        // round count isn't strictly comparable; everywhere else the
        // restored working set must not expand the search.
        if workload != "slope" {
            assert!(
                get_usize(&warm, "rounds") <= get_usize(&cold, "rounds"),
                "{workload}: warm must not use more rounds"
            );
        }
    }
}

/// The `"init"` protocol knob: a `"fista"` cold solve must converge to
/// the same objective as a `"screening"` cold solve of the same request
/// (≤ 1e-6 relative) without using more generation rounds, and bad
/// strategy values must error cleanly.
#[test]
fn fista_init_over_the_protocol() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":50,"p":160,"seed":13}}"#,
    ))
    .unwrap());
    let req = |init: &str| {
        format!(
            r#"{{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"eps":1e-7,"cache":false,"init":"{init}","max_cols_per_round":5}}"#
        )
    };
    let screening = Json::parse(&state.handle_line(&req("screening"))).unwrap();
    assert_ok(&screening);
    assert_eq!(screening.get("init").unwrap().as_str(), Some("screening"));
    let fista = Json::parse(&state.handle_line(&req("fista"))).unwrap();
    assert_ok(&fista);
    assert_eq!(fista.get("init").unwrap().as_str(), Some("fista"));
    assert_eq!(fista.get("seeded_by").unwrap().as_str(), Some("fista"));
    assert!(get_bool(&fista, "converged"));
    let so = get_f64(&screening, "objective");
    let fo = get_f64(&fista, "objective");
    assert!(
        (so - fo).abs() / so.max(1e-9) <= 1e-6,
        "fista-seeded {fo} vs screening-seeded {so}"
    );
    assert!(
        get_usize(&fista, "rounds") <= get_usize(&screening, "rounds"),
        "the FOM seed must not need more rounds: fista {} screening {}",
        get_usize(&fista, "rounds"),
        get_usize(&screening, "rounds")
    );
    // unknown strategies and the legacy numeric form are protocol errors
    for bad in [
        r#"{"op":"solve","dataset":"d","workload":"l1svm","init":"magic"}"#,
        r#"{"op":"solve","dataset":"d","workload":"l1svm","init":7}"#,
    ] {
        let resp = Json::parse(&state.handle_line(bad)).unwrap();
        assert!(!get_bool(&resp, "ok"), "{bad:?} should fail");
    }
}

/// The grid endpoint must seed the warm-start cache at every visited λ:
/// a later fixed-λ solve inside the grid's range starts warm.
#[test]
fn grid_seeds_the_cache_at_every_lambda() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":21}}"#,
    ))
    .unwrap());
    let grid = Json::parse(&state.handle_line(
        r#"{"op":"grid","dataset":"d","workload":"l1svm","grid":5,"ratio":0.6}"#,
    ))
    .unwrap();
    assert_ok(&grid);
    let seeded = get_usize(&grid, "cache_seeded");
    assert!(seeded >= 4, "expected most grid points cached, got {seeded}");
    let path = grid.get("path").unwrap().as_arr().unwrap();
    // hit an interior grid λ exactly: the solve must come back warm
    let lambda_mid = path[2].get("lambda").unwrap().as_f64().unwrap();
    let solve = Json::parse(&state.handle_line(&format!(
        r#"{{"op":"solve","dataset":"d","workload":"l1svm","lambda":{lambda_mid},"eps":1e-6}}"#
    )))
    .unwrap();
    assert_ok(&solve);
    assert!(get_bool(&solve, "warm"), "grid-visited λ must hit the cache: {solve}");
}

/// Warm-start snapshots survive the `PairSet` migration: RankSVM row
/// snapshots address the canonical pair-index space, which is derived
/// from the sorted relevance order and is identical for both pair
/// representations — so a snapshot written under `"pair_mode":
/// "enumerate"` warm-starts a `"pair_mode":"implicit"` solve (and vice
/// versa) at the same objective without extra rounds.
#[test]
fn ranksvm_snapshots_survive_pair_mode_migration() {
    let state = ServeState::new(64);
    for (name, seed, first, second) in
        [("ra", 17, "enumerate", "implicit"), ("rb", 18, "implicit", "enumerate")]
    {
        let reg = format!(
            "{{\"op\":\"register\",\"name\":\"{name}\",\"synthetic\":\
             {{\"kind\":\"ranksvm\",\"n\":28,\"p\":30,\"seed\":{seed}}}}}"
        );
        assert_ok(&Json::parse(&state.handle_line(&reg)).unwrap());
        let req = |mode: &str| {
            format!(
                r#"{{"op":"solve","dataset":"{name}","workload":"ranksvm","lambda_frac":0.05,"eps":1e-7,"pair_mode":"{mode}"}}"#
            )
        };
        let cold = Json::parse(&state.handle_line(&req(first))).unwrap();
        assert_ok(&cold);
        assert!(!get_bool(&cold, "warm"), "{name}: first solve must be cold");
        assert!(get_usize(&cold, "working_rows") > 0, "pair channel must be exercised");
        let warm = Json::parse(&state.handle_line(&req(second))).unwrap();
        assert_ok(&warm);
        assert!(
            get_bool(&warm, "warm"),
            "{name}: {first}→{second} snapshot must hit the cache: {warm}"
        );
        assert_eq!(warm.get("seeded_by").unwrap().as_str(), Some("cache"));
        let co = get_f64(&cold, "objective");
        let wo = get_f64(&warm, "objective");
        assert!(
            (wo - co).abs() / co.max(1e-9) <= 1e-6,
            "{name}: warm {wo} vs cold {co} across representations"
        );
        assert!(
            get_usize(&warm, "rounds") <= get_usize(&cold, "rounds"),
            "{name}: the migrated snapshot must not expand the search"
        );
    }
    // bad pair modes are protocol errors, not crashes
    let bad = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"ra","workload":"ranksvm","pair_mode":"magic"}"#,
    ))
    .unwrap();
    assert!(!get_bool(&bad, "ok"));
}

/// N concurrent clients must receive byte-identical responses to the
/// same requests issued serially (cache disabled so every solve is a
/// deterministic cold run).
#[test]
fn concurrent_clients_match_serial() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":30,"p":60,"seed":5}}"#,
    ))
    .unwrap());
    let requests: Vec<String> = ["l1svm", "group", "slope", "ranksvm", "dantzig"]
        .iter()
        .map(|w| {
            format!(
                r#"{{"op":"solve","dataset":"d","workload":"{w}","lambda_frac":0.1,"eps":1e-4,"cache":false}}"#
            )
        })
        .collect();
    let serial: Vec<String> = requests.iter().map(|r| state.handle_line(r)).collect();
    let mut concurrent: Vec<String> = vec![String::new(); requests.len()];
    std::thread::scope(|scope| {
        for (slot, req) in concurrent.iter_mut().zip(&requests) {
            let state = &state;
            scope.spawn(move || {
                *slot = state.handle_line(req);
            });
        }
    });
    for (k, (s, c)) in serial.iter().zip(&concurrent).enumerate() {
        assert_ok(&Json::parse(s).unwrap());
        assert_eq!(s, c, "request {k}: concurrent response diverged");
    }
}

/// The grid endpoint routes through the warm-started path drivers for
/// **all five workloads** and reports one point per λ; unknown
/// workloads fail cleanly.
#[test]
fn grid_endpoint_runs_the_warm_started_paths() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":30,"p":50,"seed":9}}"#,
    ))
    .unwrap());
    for workload in ["l1svm", "group", "slope", "ranksvm", "dantzig"] {
        let resp = Json::parse(&state.handle_line(&format!(
            r#"{{"op":"grid","dataset":"d","workload":"{workload}","grid":4,"ratio":0.6,"group_size":5}}"#
        )))
        .unwrap();
        assert_ok(&resp);
        let path = resp.get("path").unwrap().as_arr().unwrap();
        assert_eq!(path.len(), 4, "{workload}: expected 4 grid points");
        // λ decreases along the grid; λ_max comes first with an empty
        // model (Slope's chained driver re-prices epigraph cuts from
        // incumbents, so only its λ ordering is pinned here)
        if workload != "slope" {
            assert_eq!(
                path[0].get("support").unwrap().as_usize(),
                Some(0),
                "{workload}: λ_max point must have empty support"
            );
        }
        let l0 = path[0].get("lambda").unwrap().as_f64().unwrap();
        let l3 = path[3].get("lambda").unwrap().as_f64().unwrap();
        assert!(l3 < l0, "{workload}: λ must decrease along the grid");
    }
    let unsupported = Json::parse(
        &state.handle_line(r#"{"op":"grid","dataset":"d","workload":"lasso","grid":3}"#),
    )
    .unwrap();
    assert!(!get_bool(&unsupported, "ok"));
}

/// Malformed input never tears the session down: every bad line gets an
/// `{"ok":false}` response and the next request still works.
#[test]
fn protocol_errors_are_responses_not_crashes() {
    let state = ServeState::new(8);
    for bad in [
        "not json at all",
        r#"{"op":"frobnicate"}"#,
        r#"{"missing":"op"}"#,
        r#"{"op":"solve","dataset":"ghost","workload":"l1svm"}"#,
        r#"{"op":"solve","dataset":"d","workload":"lasso"}"#,
        r#"{"op":"register","name":"x"}"#,
        r#"{"op":"register","name":"x","synthetic":{"kind":"martian"}}"#,
    ] {
        let resp = Json::parse(&state.handle_line(bad)).unwrap();
        assert!(!get_bool(&resp, "ok"), "{bad:?} should fail");
        assert!(resp.get("error").unwrap().as_str().is_some());
    }
    let pong = Json::parse(&state.handle_line(r#"{"op":"ping"}"#)).unwrap();
    assert_ok(&pong);
}

/// Snapshot export → import into a fresh restricted problem restores
/// the working sets exactly and re-converges in one round at the same
/// objective (Dantzig exercises the I ⊆ J invariant through import).
#[test]
fn snapshot_roundtrip_restores_dantzig_working_sets() {
    let spec = DantzigSpec { n: 30, p: 40, k0: 5, rho: 0.1, sigma: 0.4, standardize: true };
    let ds = generate_dantzig(&spec, &mut Xoshiro256::seed_from_u64(77));
    let lambda = 0.3 * lambda_max_dantzig(&ds);
    let backend = NativeBackend::new(&ds.x);
    let params = GenParams { eps: 1e-9, ..Default::default() };
    let pricer = BackendPricer::new(&backend, 1);

    let mut cold = DantzigProblem::new(
        RestrictedDantzig::new(&ds, lambda, &initial_features(&ds, 10)),
        &ds,
        &pricer,
    );
    let engine = GenEngine::new(&params);
    let cold_stats = engine.run(&mut cold);
    assert!(cold_stats.converged);
    let ws = cold.export_working_set();
    assert!(!ws.is_empty());

    let mut fresh =
        DantzigProblem::new(RestrictedDantzig::new(&ds, lambda, &[]), &ds, &pricer);
    fresh.import_working_set(&ws);
    // same sets (insertion order may differ: import adds row-columns first)
    let restored = fresh.export_working_set();
    let sorted = |v: &[usize]| {
        let mut v = v.to_vec();
        v.sort_unstable();
        v
    };
    assert_eq!(sorted(&restored.cols), sorted(&ws.cols), "column sets must match");
    assert_eq!(restored.rows, ws.rows, "row order is preserved verbatim");
    // I ⊆ J must survive the import
    for i in fresh.inner().i_set() {
        assert!(fresh.inner().j_set().contains(i), "row {i} lacks its column pair");
    }
    let warm_stats = engine.run(&mut fresh);
    assert!(warm_stats.converged);
    assert!(
        warm_stats.rounds <= 2,
        "restored working set must price out almost immediately (rounds {})",
        warm_stats.rounds
    );
    let direct = dantzig_generation(&ds, &backend, lambda, &[], &params);
    assert!(
        (fresh.inner().objective() - direct.objective).abs() / direct.objective.max(1e-9)
            <= 1e-6,
        "restored {} direct {}",
        fresh.inner().objective(),
        direct.objective
    );
}

/// Warm-start snapshots spilled to a persist dir survive a restart: a
/// fresh `ServeState` pointed at the same directory — its in-memory
/// cache empty — warm-hits from disk, matching the cold objective to
/// ≤ 1e-6 relative with strictly fewer generation rounds, and `stats`
/// counts the disk hit.
#[test]
fn persisted_snapshots_survive_a_restart() {
    let dir =
        std::env::temp_dir().join(format!("cutgen-persist-proto-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let reg = r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":60,"p":200,"seed":7}}"#;
    let solve = r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.02,"eps":1e-6,"max_cols_per_round":5,"init":"screening"}"#;
    // first life: cold solve, snapshot spilled to disk on store
    let first = ServeState::new(64).with_persist_dir(&dir).unwrap();
    assert_ok(&Json::parse(&first.handle_line(reg)).unwrap());
    let cold = Json::parse(&first.handle_line(solve)).unwrap();
    assert_ok(&cold);
    assert!(!get_bool(&cold, "warm"), "first life must solve cold");
    assert!(get_bool(&cold, "converged"));
    drop(first);
    // second life: fresh state, same dir. The registry fingerprint is
    // content-derived, so re-registering the same synthetic spec keys
    // the same spilled snapshot.
    let second = ServeState::new(64).with_persist_dir(&dir).unwrap();
    assert_ok(&Json::parse(&second.handle_line(reg)).unwrap());
    let warm = Json::parse(&second.handle_line(solve)).unwrap();
    assert_ok(&warm);
    assert!(get_bool(&warm, "warm"), "restart must reload the spilled snapshot: {warm}");
    assert_eq!(warm.get("seeded_by").unwrap().as_str(), Some("cache"));
    let co = get_f64(&cold, "objective");
    let wo = get_f64(&warm, "objective");
    assert!(
        (wo - co).abs() / co.max(1e-9) <= 1e-6,
        "reloaded {wo} vs cold {co} at the same λ"
    );
    assert!(
        get_usize(&warm, "rounds") < get_usize(&cold, "rounds"),
        "the reloaded snapshot must save rounds: warm {}, cold {}",
        get_usize(&warm, "rounds"),
        get_usize(&cold, "rounds")
    );
    let stats = Json::parse(&second.handle_line(r#"{"op":"stats"}"#)).unwrap();
    assert!(
        get_usize(&stats, "cache_disk_hits") >= 1,
        "stats must count the disk hit: {stats}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// One `batch` request serves heterogeneous (workload, λ) items — all
/// five workloads — against a single dataset, sharing the warm cache
/// across items in order: a repeated item warm-hits the snapshot an
/// earlier item stored, per-item errors stay inline, and malformed
/// batches fail whole.
#[test]
fn batch_serves_mixed_workloads_through_one_cache() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":11}}"#,
    ))
    .unwrap());
    let batch = concat!(
        r#"{"op":"batch","dataset":"d","requests":["#,
        r#"{"workload":"l1svm","lambda_frac":0.05,"eps":1e-6},"#,
        r#"{"workload":"group","lambda_frac":0.1,"eps":1e-6},"#,
        r#"{"workload":"slope","lambda_frac":0.05,"eps":1e-6},"#,
        r#"{"workload":"ranksvm","lambda_frac":0.05,"eps":1e-6},"#,
        r#"{"workload":"dantzig","lambda_frac":0.3,"eps":1e-6},"#,
        r#"{"workload":"l1svm","lambda_frac":0.05,"eps":1e-6},"#,
        r#"{"workload":"lasso","lambda_frac":0.05}"#,
        r#"]}"#,
    );
    let resp = Json::parse(&state.handle_line(batch)).unwrap();
    assert_ok(&resp);
    assert_eq!(get_usize(&resp, "count"), 7);
    assert_eq!(get_usize(&resp, "timed_out"), 0, "no deadline was set: {resp}");
    let results = resp.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 7);
    for (k, r) in results[..6].iter().enumerate() {
        assert!(get_bool(r, "ok"), "item {k} failed: {r}");
        assert!(get_bool(r, "converged"), "item {k} must converge");
        assert!(!get_bool(r, "timed_out"), "item {k} must not time out");
    }
    // item 5 repeats item 0: it must warm-hit the snapshot item 0 stored
    assert!(get_bool(&results[5], "warm"), "repeat item must share the warm cache");
    assert!(get_usize(&resp, "warm_hits") >= 1);
    // the unknown workload fails inline without failing the batch
    assert!(!get_bool(&results[6], "ok"));
    assert!(results[6].get("error").unwrap().as_str().is_some());
    // batches themselves must be well-formed
    for bad in [
        r#"{"op":"batch","dataset":"d"}"#,
        r#"{"op":"batch","dataset":"d","requests":[]}"#,
        r#"{"op":"batch","dataset":"d","requests":"l1svm"}"#,
        r#"{"op":"batch","dataset":"ghost","requests":[{"workload":"l1svm"}]}"#,
    ] {
        let resp = Json::parse(&state.handle_line(bad)).unwrap();
        assert!(!get_bool(&resp, "ok"), "{bad:?} should fail");
    }
}

/// `"trace": true` on a solve returns the typed per-round engine events
/// inline, and they must agree with the response's own counters: one
/// event per round, per-round `cols_added` summing to the reported
/// total, the last event's cumulative `simplex_iters` matching, and the
/// per-round solve spans summing to the reported `solve_ms` (both come
/// from the same engine clocks, so they agree to rounding).
#[test]
fn trace_events_agree_with_reported_stats() {
    let state = ServeState::new(16);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":50,"p":120,"seed":31}}"#,
    ))
    .unwrap());
    let resp = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"eps":1e-7,"cache":false,"trace":true,"init":"screening","max_cols_per_round":5}"#,
    ))
    .unwrap();
    assert_ok(&resp);
    let events = resp.get("trace").unwrap().as_arr().unwrap();
    assert_eq!(get_usize(&resp, "trace_dropped"), 0);
    assert_eq!(events.len(), get_usize(&resp, "rounds"), "one event per round: {resp}");
    let cols_added: usize = events.iter().map(|e| get_usize(e, "cols_added")).sum();
    assert_eq!(cols_added, get_usize(&resp, "cols_added"));
    let last = events.last().unwrap();
    assert_eq!(get_usize(last, "simplex_iters"), get_usize(&resp, "simplex_iters"));
    for (k, e) in events.iter().enumerate() {
        assert_eq!(get_usize(e, "round"), k + 1, "rounds are 1-based and consecutive");
    }
    // span totals: the per-round solve clocks sum to the reported
    // solve_ms, and the full breakdown fits inside the request wall time
    let solve_ns: f64 = events.iter().map(|e| get_f64(e, "solve_ns")).sum();
    let solve_ms = get_f64(&resp, "solve_ms");
    assert!(
        (solve_ns / 1e6 - solve_ms).abs() <= 1e-3 + solve_ms * 1e-6,
        "per-round solve spans {solve_ns}ns vs reported {solve_ms}ms"
    );
    let wall_ms = get_f64(&resp, "wall_ms");
    let parts = solve_ms + get_f64(&resp, "pricing_ms") + get_f64(&resp, "seed_ms");
    assert!(
        parts <= wall_ms,
        "span breakdown ({parts}ms) cannot exceed the wall clock ({wall_ms}ms): {resp}"
    );
    // untraced responses carry none of the nondeterministic fields
    let plain = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"cache":false}"#,
    ))
    .unwrap();
    assert_ok(&plain);
    for absent in ["trace", "wall_ms", "solve_ms"] {
        assert!(plain.get(absent).is_none(), "{absent} must be trace-gated: {plain}");
    }
}

/// The `metrics` op: after real traffic the exposition text must carry
/// the request-latency histogram, per-op request counters, and cache
/// counters that agree with the `stats` op — and every line must parse
/// as Prometheus text exposition (`# HELP`/`# TYPE` or `name{…} value`).
#[test]
fn metrics_op_renders_agreeing_exposition() {
    let state = ServeState::new(16);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":23}}"#,
    ))
    .unwrap());
    let solve = r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"eps":1e-6}"#;
    assert_ok(&Json::parse(&state.handle_line(solve)).unwrap());
    assert_ok(&Json::parse(&state.handle_line(solve)).unwrap()); // warm hit
    let stats = Json::parse(&state.handle_line(r#"{"op":"stats"}"#)).unwrap();
    let metrics = Json::parse(&state.handle_line(r#"{"op":"metrics"}"#)).unwrap();
    assert_ok(&metrics);
    let text = metrics.get("exposition").unwrap().as_str().unwrap().to_string();
    // the request-latency histogram saw both solves
    assert!(
        text.contains(
            "cutgen_request_latency_seconds_bucket{op=\"solve\",workload=\"l1svm\",le=\"+Inf\"} 2"
        ),
        "missing solve latency histogram:\n{text}"
    );
    assert!(text.contains("cutgen_request_latency_seconds_count{op=\"solve\",workload=\"l1svm\"} 2"));
    assert!(text.contains("cutgen_requests_total{op=\"solve\"} 2"), "got:\n{text}");
    assert!(text.contains("cutgen_requests_total{op=\"register\"} 1"));
    // scrape-time mirrors agree with the stats op
    let hits = get_usize(&stats, "cache_hits");
    let misses = get_usize(&stats, "cache_misses");
    assert!(hits >= 1, "second solve must warm-hit: {stats}");
    assert!(text.contains(&format!("cutgen_cache_hits_total {hits}")), "got:\n{text}");
    assert!(text.contains(&format!("cutgen_cache_misses_total {misses}")));
    assert!(text.contains("cutgen_inflight 0"), "no solve is executing at scrape time");
    assert!(
        text.contains("cutgen_dataset_resident_bytes{dataset=\"d\"}"),
        "per-dataset gauge missing:\n{text}"
    );
    // well-formed exposition: HELP/TYPE headers or `name{…} value` lines
    for line in text.lines() {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad: {line}"));
        assert!(!series.is_empty());
        assert!(
            value == "+Inf" || value.parse::<f64>().is_ok(),
            "unparseable sample value in {line:?}"
        );
    }
    // counters are monotone across scrapes
    let again = Json::parse(&state.handle_line(r#"{"op":"metrics"}"#)).unwrap();
    let text2 = again.get("exposition").unwrap().as_str().unwrap();
    assert!(
        text2.contains("cutgen_requests_total{op=\"metrics\"} 1"),
        "the first metrics scrape is itself counted:\n{text2}"
    );
}

/// Grid responses carry per-point engine stats (`rounds`,
/// `simplex_iters`, `warm`, `timed_out`) plus `warm_hits`/`timed_out`
/// rollups, and `"trace": true` returns ring-buffered round events that
/// account for every generation round the path drivers ran.
#[test]
fn grid_reports_per_point_stats_and_rollups() {
    let state = ServeState::new(16);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":27}}"#,
    ))
    .unwrap());
    let resp = Json::parse(&state.handle_line(
        r#"{"op":"grid","dataset":"d","workload":"l1svm","grid":4,"ratio":0.6,"trace":true}"#,
    ))
    .unwrap();
    assert_ok(&resp);
    let path = resp.get("path").unwrap().as_arr().unwrap();
    assert_eq!(path.len(), 4);
    assert!(!get_bool(&path[0], "warm"), "λ_max point starts cold");
    for pt in &path[1..] {
        assert!(get_bool(pt, "warm"), "later points warm-start from their predecessor");
    }
    for pt in path {
        assert!(!get_bool(pt, "timed_out"), "no deadline was set: {pt}");
    }
    assert_eq!(get_usize(&resp, "warm_hits"), 3);
    assert_eq!(get_usize(&resp, "timed_out"), 0);
    // per-point rounds sum to the path total, which is what the ring saw
    let per_point: usize = path.iter().map(|pt| get_usize(pt, "rounds")).sum();
    assert_eq!(per_point, get_usize(&resp, "rounds"), "step rounds must sum: {resp}");
    let events = resp.get("trace").unwrap().as_arr().unwrap();
    assert_eq!(get_usize(&resp, "trace_dropped"), 0);
    assert_eq!(events.len(), per_point, "one traced event per engine round");
}

/// The TCP transport: worker pool serves a multi-request session, and a
/// `shutdown` request stops the server.
#[test]
fn tcp_transport_session_and_shutdown() {
    let state = ServeState::new(16);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        let state_ref = &state;
        let server = scope.spawn(move || serve_tcp(state_ref, listener, 2, 16));
        let lines: Vec<String> = vec![
            r#"{"op":"register","name":"t","synthetic":{"kind":"l1","n":25,"p":40,"seed":3}}"#
                .to_string(),
            r#"{"op":"solve","dataset":"t","workload":"l1svm","lambda_frac":0.1}"#.to_string(),
            r#"{"op":"stats"}"#.to_string(),
        ];
        let responses = client_send_many(&addr, &lines).unwrap();
        assert_eq!(responses.len(), 3);
        for r in &responses {
            assert_ok(&Json::parse(r).unwrap());
        }
        let bye = client_send(&addr, r#"{"op":"shutdown"}"#).unwrap();
        assert_ok(&Json::parse(&bye).unwrap());
        server.join().unwrap().unwrap();
    });
}

/// The dynamic-λ controller over the protocol: a `"target_ratio"` solve
/// resolves λ itself, reports the controller bookkeeping, and caches the
/// converged working set under the **resolved** λ — where a later
/// fixed-λ request finds it warm.
#[test]
fn target_ratio_resolves_lambda_and_caches_at_it() {
    let state = ServeState::new(64);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"r","synthetic":{"kind":"l1","n":30,"p":40,"seed":5}}"#,
    ))
    .unwrap());
    let resp = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"r","workload":"ranksvm","target_ratio":2.0,"ratio_tol":0.25,"eps":1e-6}"#,
    ))
    .unwrap();
    assert_ok(&resp);
    let lambda = get_f64(&resp, "lambda");
    assert!(lambda > 0.0 && lambda < get_f64(&resp, "lambda_max"));
    assert_eq!(resp.get("seeded_by").and_then(Json::as_str), Some("controller"));
    let achieved = get_f64(&resp, "achieved_ratio");
    assert!(
        (achieved - 2.0).abs() <= 0.25 * 2.0,
        "achieved ratio {achieved} outside tolerance of target 2.0"
    );
    assert!(get_usize(&resp, "controller_solves") >= 1);
    assert_eq!(resp.get("pair_scan").and_then(Json::as_str), Some("uniform"));
    assert!(!get_bool(&resp, "warm"));
    // a fixed-λ solve at the resolved λ must hit the controller's snapshot
    let warm = Json::parse(&state.handle_line(&format!(
        r#"{{"op":"solve","dataset":"r","workload":"ranksvm","lambda":{lambda},"eps":1e-6}}"#
    )))
    .unwrap();
    assert_ok(&warm);
    assert!(get_bool(&warm, "warm"), "cache must be keyed on the resolved λ: {warm}");
    let wo = get_f64(&warm, "objective");
    let co = get_f64(&resp, "objective");
    assert!((wo - co).abs() / co.max(1e-9) <= 1e-6, "warm {wo} vs controller {co}");

    // misuse errors are typed and do not crash the session
    let bad_wl = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"r","workload":"l1svm","target_ratio":2.0}"#,
    ))
    .unwrap();
    assert!(!get_bool(&bad_wl, "ok"), "target_ratio is ranksvm-only: {bad_wl}");
    let both = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"r","workload":"ranksvm","target_ratio":2.0,"lambda":0.5}"#,
    ))
    .unwrap();
    assert!(!get_bool(&both, "ok"), "lambda and target_ratio conflict: {both}");
    let unreachable = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"r","workload":"ranksvm","target_ratio":1e-12}"#,
    ))
    .unwrap();
    assert!(!get_bool(&unreachable, "ok"));
    let msg = unreachable.get("error").and_then(Json::as_str).unwrap_or("");
    assert!(msg.contains("bracket exhausted"), "typed exhaustion reason, got {msg:?}");

    // batch items may carry target_ratio too
    let batch = Json::parse(&state.handle_line(
        r#"{"op":"batch","dataset":"r","requests":[{"workload":"ranksvm","target_ratio":2.0,"ratio_tol":0.5},{"workload":"ranksvm","lambda_frac":0.05}]}"#,
    ))
    .unwrap();
    assert_ok(&batch);
    let results = batch.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), 2);
    for r in results {
        assert_ok(r);
    }
    assert!(results[0].get("achieved_ratio").is_some());
}

/// The `update` op cannot re-key pair-indexed RankSVM snapshots (their
/// rows address the parent's pair enumeration); it must say so
/// structurally instead of silently cold-solving.
#[test]
fn update_reports_pair_indexed_snapshots_skipped() {
    let state = ServeState::new(64);
    for line in [
        r#"{"op":"register","name":"p","synthetic":{"kind":"l1","n":24,"p":30,"seed":9}}"#,
        r#"{"op":"solve","dataset":"p","workload":"l1svm","lambda_frac":0.05}"#,
        r#"{"op":"solve","dataset":"p","workload":"ranksvm","lambda_frac":0.05}"#,
    ] {
        assert_ok(&Json::parse(&state.handle_line(line)).unwrap());
    }
    let upd = Json::parse(&state.handle_line(
        r#"{"op":"update","dataset":"p","name":"p2","retire":[0,1]}"#,
    ))
    .unwrap();
    assert_ok(&upd);
    assert!(get_usize(&upd, "cache_translated") >= 1, "feature-indexed snapshots carry over");
    assert_eq!(
        upd.get("snapshot_skipped").and_then(Json::as_str),
        Some("pair-indexed"),
        "skipped ranksvm snapshots must be reported: {upd}"
    );
    assert!(get_usize(&upd, "snapshot_skipped_count") >= 1);
    // the child really does start cold on the pair workload
    let child = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"p2","workload":"ranksvm","lambda_frac":0.05}"#,
    ))
    .unwrap();
    assert_ok(&child);
    assert!(!get_bool(&child, "warm"), "pair snapshots must not leak to the child: {child}");
    // an update whose parent has no ranksvm snapshots omits the field
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"q","synthetic":{"kind":"l1","n":20,"p":20,"seed":10}}"#,
    ))
    .unwrap());
    let upd2 = Json::parse(&state.handle_line(
        r#"{"op":"update","dataset":"q","name":"q2","retire":[0]}"#,
    ))
    .unwrap();
    assert_ok(&upd2);
    assert!(upd2.get("snapshot_skipped").is_none(), "no skip to report: {upd2}");
}
