//! Brute-force oracles for the weighted, gapped RankSVM pair channel
//! and property tests for the dynamic-λ controller.
//!
//! The pricing oracle enumerates `ranking_pairs_costed` — the O(n²)
//! reference that re-derives levels from `y` without touching
//! [`PairSet`] — and replays the winner-best rule by hand; every scan
//! the production code can pick (uniform sweep, bucketed O(n·L) sweep,
//! enumerated-list walk, streamed per-pair fallback) must return the
//! same violated-pair set under exclusions, caps, ties, NaN relevance,
//! and non-uniform per-level gaps. The bucketed sweep must additionally
//! be bit-identical at any thread count. Controller properties: the
//! resolved λ is monotone in the target ratio, the achieved ratio is
//! the real full-problem `hinge_w/‖β‖₁` within tolerance, and
//! unreachable targets surface as the typed bracket-exhausted error.
//! Uniform costs (g = 1, w = 1) must reproduce the unweighted paths
//! bitwise. See docs/ranksvm-scaling.md.

use cutgen::backend::NativeBackend;
use cutgen::baselines::ranksvm_full::{solve_full_ranksvm, solve_full_ranksvm_weighted};
use cutgen::coordinator::controller::{resolve_lambda_for_ratio, ControllerError};
use cutgen::coordinator::GenParams;
use cutgen::data::synthetic::{generate_ranksvm, RankSpec};
use cutgen::data::Dataset;
use cutgen::engine::{PairMode, RatioTarget};
use cutgen::rng::Xoshiro256;
use cutgen::workloads::pairset::{PairCosts, PairScan, PairSet};
use cutgen::workloads::ranksvm::{
    lambda_max_rank, lambda_max_rank_weighted, pairwise_hinge_support_weighted, ranking_pairs,
    ranking_pairs_costed, ranksvm_generation, ranksvm_generation_costed,
};

/// Relevance vector with everything the index space must survive:
/// tied responses (levels with several members), NaN relevance
/// (participates in no pair), an odd level (0.5), and enough spread
/// for 5 distinct levels.
fn gnarly_y() -> Vec<f64> {
    vec![
        2.0,
        0.0,
        1.0,
        f64::NAN,
        1.0,
        2.0,
        0.0,
        3.0,
        1.0,
        f64::NAN,
        3.0,
        0.5,
        2.0,
        1.0,
    ]
}

/// The three cost shapes under test, built against `pairs`' level
/// structure: uniform, a bucketed table with non-uniform per-level
/// gaps AND weights, and a per-pair table that starts from the
/// bucketed expansion and then perturbs every third entry so no
/// bucket structure survives.
fn cost_suite(y: &[f64], pairs: &PairSet) -> Vec<(&'static str, PairCosts)> {
    let bucketed = PairCosts::bucketed_by(pairs, |a, b| {
        (0.5 + 0.35 * (a - b) as f64, 1.0 + 0.5 * a as f64 + 0.25 * b as f64)
    });
    bucketed.validate(pairs).expect("bucketed table must validate");
    let costed = ranking_pairs_costed(y, &bucketed);
    let mut gaps: Vec<f64> = costed.iter().map(|c| c.2).collect();
    let mut weights: Vec<f64> = costed.iter().map(|c| c.3).collect();
    for t in (0..gaps.len()).step_by(3) {
        gaps[t] += 0.17 * ((t % 5) as f64 + 1.0);
        weights[t] *= 1.0 + 0.1 * ((t % 7) as f64);
    }
    let per_pair = PairCosts::PerPair { gaps, weights };
    per_pair.validate(pairs).expect("per-pair table must validate");
    vec![("uniform", PairCosts::UNIFORM), ("bucketed", bucketed), ("per-pair", per_pair)]
}

/// The O(n²) pricing oracle: replay the winner-best rule over the
/// reference enumeration — canonical order, first-wins on violation
/// ties, `viol > eps` threshold, global `(viol desc, t asc)` order,
/// then the cap. Uses the same `w·(g − (m_i − m_k))` expression as
/// every production scan, so agreement is exact up to summation-free
/// arithmetic.
fn brute_price(
    y: &[f64],
    costs: &PairCosts,
    m: &[f64],
    eps: f64,
    excluded: &[usize],
    cap: usize,
) -> Vec<(usize, f64)> {
    let costed = ranking_pairs_costed(y, costs);
    let mut out: Vec<(usize, f64)> = Vec::new();
    let mut cur: Option<(usize, usize, f64)> = None; // (winner, t, viol)
    for (t, &(i, k, g, w)) in costed.iter().enumerate() {
        if excluded.binary_search(&t).is_ok() {
            continue;
        }
        let viol = w * (g - (m[i] - m[k]));
        match cur {
            Some((wn, _, bv)) if wn == i => {
                if viol > bv {
                    cur = Some((i, t, viol));
                }
            }
            Some((_, bt, bv)) => {
                if bv > eps {
                    out.push((bt, bv));
                }
                cur = Some((i, t, viol));
            }
            None => cur = Some((i, t, viol)),
        }
    }
    if let Some((_, bt, bv)) = cur {
        if bv > eps {
            out.push((bt, bv));
        }
    }
    out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    if cap > 0 && out.len() > cap {
        out.truncate(cap);
    }
    out
}

fn assert_pricing_eq(got: &[(usize, f64)], want: &[(usize, f64)], label: &str) {
    let gi: Vec<usize> = got.iter().map(|c| c.0).collect();
    let wi: Vec<usize> = want.iter().map(|c| c.0).collect();
    assert_eq!(gi, wi, "{label}: violated-pair sets differ");
    for ((gt, gv), (_, wv)) in got.iter().zip(want) {
        assert!(
            (gv - wv).abs() <= 1e-12,
            "{label}: violation of pair {gt} is {gv}, oracle says {wv}"
        );
    }
}

/// Every scan the dispatcher can pick — uniform sweep, bucketed
/// sweep, enumerated-list walk, streamed per-pair fallback — agrees
/// with the O(n²) oracle on the violated-pair set, across eps
/// thresholds, caps, working-set exclusions, tied/NaN relevance, and
/// non-uniform per-level gaps. The typed scan reason must name the
/// strategy that actually applies.
#[test]
fn weighted_pricing_matches_the_brute_force_oracle() {
    let y = gnarly_y();
    let implicit = PairSet::build(&y, PairMode::Implicit);
    let enumerated = PairSet::build(&y, PairMode::Enumerate);
    assert!(!implicit.is_enumerated() && enumerated.is_enumerated());
    assert_eq!(implicit.len(), ranking_pairs(&y).len(), "canonical spaces must align");

    let mut rng = Xoshiro256::seed_from_u64(0x0A1B2C3D);
    for trial in 0..6usize {
        let m: Vec<f64> = y.iter().map(|_| rng.uniform_in(-2.0, 2.0)).collect();
        let excluded: Vec<usize> =
            (0..implicit.len()).filter(|t| (t * 7 + trial) % 5 == 0).collect();
        for eps in [0.0, 0.25] {
            for cap in [0usize, 3, 1000] {
                for (cname, costs) in cost_suite(&y, &implicit) {
                    let want = brute_price(&y, &costs, &m, eps, &excluded, cap);
                    let (got_i, scan_i) =
                        implicit.price_weighted(&m, eps, &excluded, cap, 1, &costs);
                    let (got_e, scan_e) =
                        enumerated.price_weighted(&m, eps, &excluded, cap, 1, &costs);
                    let label =
                        format!("trial {trial} eps {eps} cap {cap} costs {cname}");
                    assert_pricing_eq(&got_i, &want, &format!("{label} implicit"));
                    assert_pricing_eq(&got_e, &want, &format!("{label} enumerated"));
                    let want_scan_i = match &costs {
                        PairCosts::Uniform => PairScan::Uniform,
                        PairCosts::Bucketed { .. } => PairScan::Bucketed,
                        PairCosts::PerPair { .. } => PairScan::EnumeratedPerPair,
                    };
                    assert_eq!(scan_i, want_scan_i, "{label}: implicit scan reason");
                    let want_scan_e = if costs.is_uniform() {
                        PairScan::Uniform
                    } else {
                        PairScan::EnumeratedList
                    };
                    assert_eq!(scan_e, want_scan_e, "{label}: enumerated scan reason");
                }
            }
        }
    }
}

/// The bucketed O(n·L) sweep chunks winners over worker threads; the
/// per-winner result must not depend on the chunking. n is pushed past
/// the serial cutoff so threads > 1 genuinely split the scan, and the
/// comparison is bitwise (`to_bits`), not a tolerance.
#[test]
fn bucketed_sweep_is_bitwise_identical_across_thread_counts() {
    let n = 5000usize;
    let y: Vec<f64> = (0..n).map(|i| (i % 6) as f64).collect();
    let ps = PairSet::build(&y, PairMode::Implicit);
    let costs = PairCosts::bucketed_by(&ps, |a, b| {
        (1.0 + 0.5 * (a - b) as f64, 1.0 + 0.25 * b as f64)
    });
    costs.validate(&ps).expect("table must validate");
    let mut rng = Xoshiro256::seed_from_u64(0xFEED);
    let m: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let excluded: Vec<usize> = (0..ps.len()).step_by(9973).collect();
    let (base, scan) = ps.price_weighted(&m, 1e-6, &excluded, 64, 1, &costs);
    assert_eq!(scan, PairScan::Bucketed);
    assert!(!base.is_empty(), "the scan must surface violated pairs");
    for threads in [2usize, 4] {
        let (got, _) = ps.price_weighted(&m, 1e-6, &excluded, 64, threads, &costs);
        assert_eq!(got.len(), base.len(), "threads {threads}: candidate count");
        for ((gt, gv), (bt, bv)) in got.iter().zip(&base) {
            assert_eq!(gt, bt, "threads {threads}: pair index drifted");
            assert_eq!(
                gv.to_bits(),
                bv.to_bits(),
                "threads {threads}: violation of pair {gt} not bitwise stable"
            );
        }
    }
}

/// The aggregate channels agree with the reference enumeration:
/// `hinge_weighted` with a brute-force weighted hinge sum,
/// `weighted_dual` with the brute-force `±w_t` scatter — on both
/// representations, all three cost shapes.
#[test]
fn weighted_hinge_and_dual_match_the_reference_enumeration() {
    let y = gnarly_y();
    let mut rng = Xoshiro256::seed_from_u64(0x5EED);
    let m: Vec<f64> = y.iter().map(|_| rng.uniform_in(-1.5, 1.5)).collect();
    for mode in [PairMode::Implicit, PairMode::Enumerate] {
        let ps = PairSet::build(&y, mode);
        for (cname, costs) in cost_suite(&y, &ps) {
            let costed = ranking_pairs_costed(&y, &costs);
            let want_hinge: f64 =
                costed.iter().map(|&(i, k, g, w)| w * (g - (m[i] - m[k])).max(0.0)).sum();
            let got_hinge = ps.hinge_weighted(&m, &costs);
            assert!(
                (got_hinge - want_hinge).abs() <= 1e-9 * want_hinge.abs().max(1.0),
                "{mode:?} {cname}: hinge {got_hinge} vs oracle {want_hinge}"
            );
            let mut want_dual = vec![0.0; y.len()];
            for &(i, k, _, w) in &costed {
                want_dual[i] += w;
                want_dual[k] -= w;
            }
            let got_dual = ps.weighted_dual(&costs);
            for (s, (g, w)) in got_dual.iter().zip(&want_dual).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-9,
                    "{mode:?} {cname}: dual scatter at sample {s}: {g} vs {w}"
                );
            }
        }
    }
}

fn rank_fixture(n: usize, p: usize, seed: u64) -> Dataset {
    let spec = RankSpec { n, p, k0: 4.min(p), rho: 0.1, noise: 0.3, standardize: true };
    generate_ranksvm(&spec, &mut Xoshiro256::seed_from_u64(seed))
}

/// Uniform costs ARE the unweighted problem: λ_max, pricing, hinge,
/// generation, and the full LP all reproduce their unweighted
/// counterparts bitwise when every gap is 1 and every weight is 1.
#[test]
fn uniform_costs_reproduce_the_unweighted_paths_bitwise() {
    let ds = rank_fixture(22, 24, 7);
    let pairs = PairSet::build(&ds.y, PairMode::Auto);
    let backend = NativeBackend::new(&ds.x);
    let params = GenParams { eps: 1e-8, ..Default::default() };

    let lmax = lambda_max_rank(&ds, &pairs);
    assert_eq!(
        lmax.to_bits(),
        lambda_max_rank_weighted(&ds, &pairs, &PairCosts::UNIFORM).to_bits(),
        "weighted λ_max must equal the unweighted one bitwise"
    );

    let mut rng = Xoshiro256::seed_from_u64(0xCAFE);
    let m: Vec<f64> = (0..ds.n()).map(|_| rng.normal()).collect();
    let plain = pairs.price(&m, 1e-6, &[], 16, 1);
    let (weighted, scan) = pairs.price_weighted(&m, 1e-6, &[], 16, 1, &PairCosts::UNIFORM);
    assert_eq!(scan, PairScan::Uniform);
    assert_eq!(plain.len(), weighted.len());
    for ((pt, pv), (wt, wv)) in plain.iter().zip(&weighted) {
        assert_eq!(pt, wt);
        assert_eq!(pv.to_bits(), wv.to_bits(), "uniform pricing must be bitwise identical");
    }
    assert_eq!(
        pairs.hinge(&m).to_bits(),
        pairs.hinge_weighted(&m, &PairCosts::UNIFORM).to_bits(),
        "uniform hinge must be bitwise identical"
    );

    for frac in [0.5, 0.1] {
        let lambda = frac * lmax;
        let a = ranksvm_generation(&ds, &backend, &pairs, lambda, &[], &[], &params);
        let b = ranksvm_generation_costed(
            &ds,
            &backend,
            &pairs,
            &PairCosts::UNIFORM,
            lambda,
            &[],
            &[],
            &params,
        );
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "objective at λ = {lambda}");
        assert_eq!(a.beta.len(), b.beta.len());
        for (j, (x, y)) in a.beta.iter().zip(&b.beta).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "β[{j}] at λ = {lambda}");
        }
        assert_eq!(a.cols, b.cols);
        assert_eq!(a.rows, b.rows);
        assert_eq!(b.stats.pair_scan, Some("uniform"));
    }

    let list = ranking_pairs(&ds.y);
    let costed: Vec<(usize, usize, f64, f64)> =
        list.iter().map(|&(i, k)| (i, k, 1.0, 1.0)).collect();
    let fa = solve_full_ranksvm(&ds, &list, 0.3 * lmax);
    let fb = solve_full_ranksvm_weighted(&ds, &costed, 0.3 * lmax);
    assert_eq!(fa.objective.to_bits(), fb.objective.to_bits(), "full-LP objective");
    for (j, (x, y)) in fa.beta.iter().zip(&fb.beta).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "full-LP β[{j}]");
    }
}

/// Weighted/gapped generation is checked against an independent
/// construction: the full weighted LP over the reference enumeration.
/// Both representations of the pair channel (enumerated list walk,
/// implicit bucketed sweep) must land on the full LP's objective.
#[test]
fn weighted_generation_agrees_with_the_weighted_full_lp() {
    let ds = rank_fixture(18, 20, 19);
    let enumerated = PairSet::build(&ds.y, PairMode::Enumerate);
    let implicit = PairSet::build(&ds.y, PairMode::Implicit);
    let backend = NativeBackend::new(&ds.x);
    let params = GenParams { eps: 1e-8, ..Default::default() };
    let costs = PairCosts::bucketed_by(&enumerated, |a, b| {
        (1.0 + 0.4 * (a - b - 1) as f64, 1.0 + 0.3 * b as f64)
    });
    costs.validate(&enumerated).expect("table must validate");
    let lmaxw = lambda_max_rank_weighted(&ds, &enumerated, &costs);
    let reference = ranking_pairs_costed(&ds.y, &costs);
    for frac in [0.4, 0.15] {
        let lambda = frac * lmaxw;
        let full = solve_full_ranksvm_weighted(&ds, &reference, lambda);
        for (ps, want_scan) in [(&enumerated, "enumerated-list"), (&implicit, "bucketed")] {
            let sol =
                ranksvm_generation_costed(&ds, &backend, ps, &costs, lambda, &[], &[], &params);
            assert_eq!(sol.stats.pair_scan, Some(want_scan));
            let rel = (sol.objective - full.objective).abs() / full.objective.abs().max(1e-9);
            assert!(
                rel <= 1e-6,
                "{want_scan} at λ = {lambda}: generation {} vs full LP {}",
                sol.objective,
                full.objective
            );
        }
    }
}

// ---------------------------------------------------------------------------
// controller properties
// ---------------------------------------------------------------------------

/// Over an increasing ladder of target ratios the resolved λ is
/// non-decreasing (more slack per unit of ‖β‖₁ needs more
/// regularization), and every achieved ratio really is the
/// full-problem `hinge_w/‖β‖₁` of the returned solution, within
/// tolerance of the target.
#[test]
fn controller_lambda_is_monotone_and_ratio_is_the_real_one() {
    let ds = rank_fixture(20, 16, 44);
    let pairs = PairSet::build(&ds.y, PairMode::Auto);
    let backend = NativeBackend::new(&ds.x);
    let params = GenParams { eps: 1e-8, ..Default::default() };
    let costs = PairCosts::bucketed_by(&pairs, |a, b| (1.0 + 0.3 * (a - b) as f64, 1.25));
    costs.validate(&pairs).expect("table must validate");

    let mut resolved: Vec<(f64, f64)> = Vec::new(); // (target, λ)
    for ratio in [0.5, 2.0, 8.0] {
        let target = RatioTarget { ratio, tol: 0.1, ..Default::default() };
        let out = match resolve_lambda_for_ratio(
            &ds, &backend, &pairs, &costs, &target, &params, None,
        ) {
            Ok(out) => out,
            // a target sitting on a support-change discontinuity of
            // r(λ) may exhaust the bracket — that is the typed escape,
            // not a landing, and the λ-monotonicity claim skips it
            Err(ControllerError::BracketExhausted { achieved, solves, .. }) => {
                assert!(solves >= 1 && achieved.is_finite());
                continue;
            }
            Err(other) => panic!("target {ratio}: unexpected error {other}"),
        };
        assert!(
            (out.achieved_ratio - ratio).abs() <= 0.1 * ratio + 1e-12,
            "target {ratio}: achieved {}",
            out.achieved_ratio
        );
        assert!(out.lambda > 0.0 && out.lambda <= out.lambda_max);
        assert!(out.solves >= 1 && out.solves <= target.max_solves);
        assert_eq!(out.total.pair_scan, Some("enumerated-list"));

        // the achieved ratio is recomputable from the returned β
        let cols: Vec<usize> = (0..out.solution.beta.len())
            .filter(|&j| out.solution.beta[j] != 0.0)
            .collect();
        let vals: Vec<f64> = cols.iter().map(|&j| out.solution.beta[j]).collect();
        let hinge = pairwise_hinge_support_weighted(&ds, &pairs, &costs, &cols, &vals);
        let l1: f64 = vals.iter().map(|v| v.abs()).sum();
        assert!(l1 > 0.0, "target {ratio}: a within-tolerance solve cannot have β = 0");
        let recomputed = hinge / l1;
        assert!(
            (recomputed - out.achieved_ratio).abs() <= 1e-6 * out.achieved_ratio.max(1.0),
            "target {ratio}: reported {} but β gives {recomputed}",
            out.achieved_ratio
        );
        resolved.push((ratio, out.lambda));
    }
    assert!(
        resolved.len() >= 2,
        "at least two targets on the ladder must land: {resolved:?}"
    );
    for w in resolved.windows(2) {
        assert!(
            w[1].1 >= w[0].1,
            "λ must be monotone in the target: {:?} then {:?}",
            w[0],
            w[1]
        );
    }
}

/// An unreachable target is a typed error carrying the closest probe —
/// never a silent clamp — and its Display names the exhaustion.
#[test]
fn controller_types_the_bracket_exhausted_escape() {
    let ds = rank_fixture(20, 16, 44);
    let pairs = PairSet::build(&ds.y, PairMode::Auto);
    let backend = NativeBackend::new(&ds.x);
    let params = GenParams::default();
    let target = RatioTarget { ratio: 1e-9, tol: 0.05, lo_frac: 0.9, ..Default::default() };
    let err = resolve_lambda_for_ratio(
        &ds,
        &backend,
        &pairs,
        &PairCosts::UNIFORM,
        &target,
        &params,
        None,
    )
    .expect_err("a target far below the bracket must be a typed error");
    match &err {
        ControllerError::BracketExhausted { target: t, achieved, lambda, solves } => {
            assert_eq!(*t, 1e-9);
            assert!(*achieved > *t, "closest probe {achieved} must overshoot");
            assert!(*lambda > 0.0 && *solves >= 1);
        }
        other => panic!("expected BracketExhausted, got {other:?}"),
    }
    assert!(format!("{err}").contains("bracket exhausted"));
}
