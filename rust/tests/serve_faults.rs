//! Fault-injection tests for the solve service: hostile or broken
//! clients must draw typed `{"ok":false,…}` responses — never a panic,
//! a torn session, or a leaked worker. Covers malformed and truncated
//! JSON frames, invalid UTF-8, oversized requests, slow-loris writes,
//! mid-solve client disconnects, shutdown racing a solve, admission
//! control under saturation, corrupted snapshot spills, and the
//! serve-level deadline contract.
//!
//! CI runs this suite single-threaded (`--test-threads=1`): several
//! tests own TCP listeners and wall-clock timing, and serializing them
//! keeps the timing assertions honest on loaded runners.

use std::io::{BufRead, BufReader, Cursor, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use cutgen::serve::json::Json;
use cutgen::serve::transport::{
    client_send, client_send_many, serve_lines, serve_tcp, MAX_LINE_BYTES,
};
use cutgen::serve::ServeState;

fn get_usize(v: &Json, key: &str) -> usize {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_usize().unwrap()
}

fn get_f64(v: &Json, key: &str) -> f64 {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_f64().unwrap()
}

fn get_bool(v: &Json, key: &str) -> bool {
    v.get(key).unwrap_or_else(|| panic!("missing {key} in {v}")).as_bool().unwrap()
}

fn assert_ok(v: &Json) {
    assert!(get_bool(v, "ok"), "request failed: {v}");
}

fn assert_err(v: &Json) {
    assert!(!get_bool(v, "ok"), "expected a typed error, got: {v}");
    assert!(v.get("error").unwrap().as_str().is_some(), "errors carry a message: {v}");
}

const REGISTER: &str =
    r#"{"op":"register","name":"d","synthetic":{"kind":"l1","n":40,"p":80,"seed":11}}"#;

/// Every malformed or truncated frame gets its own typed error response
/// and the session keeps serving — including raw bytes that are not
/// valid UTF-8, which a `String`-based reader would have torn down.
#[test]
fn malformed_frames_get_typed_errors_and_the_session_survives() {
    let state = ServeState::new(8);
    let mut script: Vec<u8> = Vec::new();
    script.extend_from_slice(b"not json at all\n");
    script.extend_from_slice(b"{\"op\":\"pi\n"); // truncated mid-string
    script.extend_from_slice(b"{\"op\":\"solve\",\n"); // truncated mid-object
    script.extend_from_slice(b"\xff\xfe\x80bad bytes\n"); // invalid UTF-8
    script.extend_from_slice(b"\n"); // blank lines are skipped, not answered
    script.extend_from_slice(b"{\"op\":\"ping\"}\n");
    script.extend_from_slice(b"{\"op\":\"ping\"}"); // unterminated EOF line still served
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&state, Cursor::new(script), &mut out).unwrap();
    let resp: Vec<Json> = std::str::from_utf8(&out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e}")))
        .collect();
    assert_eq!(resp.len(), 6, "four errors + two pongs (blank line skipped)");
    for r in &resp[..4] {
        assert_err(r);
    }
    assert!(
        resp[3].get("error").unwrap().as_str().unwrap().contains("UTF-8"),
        "the byte-garbage line must name the encoding problem: {}",
        resp[3]
    );
    assert_ok(&resp[4]);
    assert_ok(&resp[5]);
}

/// A request line past [`MAX_LINE_BYTES`] draws a typed error and is
/// discarded whole; the next line is served normally.
#[test]
fn oversized_lines_are_rejected_and_the_session_recovers() {
    let state = ServeState::new(8);
    let mut script: Vec<u8> = Vec::with_capacity(MAX_LINE_BYTES + 64);
    script.extend_from_slice(br#"{"op":"ping","pad":""#);
    script.resize(MAX_LINE_BYTES + 10, b'a');
    script.extend_from_slice(b"\"}\n");
    script.extend_from_slice(b"{\"op\":\"ping\"}\n");
    let mut out: Vec<u8> = Vec::new();
    serve_lines(&state, Cursor::new(script), &mut out).unwrap();
    let resp: Vec<Json> = std::str::from_utf8(&out)
        .unwrap()
        .lines()
        .map(|l| Json::parse(l).unwrap())
        .collect();
    assert_eq!(resp.len(), 2);
    assert_err(&resp[0]);
    assert!(
        resp[0].get("error").unwrap().as_str().unwrap().contains("exceeds"),
        "oversized rejection must say so: {}",
        resp[0]
    );
    assert_ok(&resp[1]);
}

/// Slow-loris defense over TCP: a client trickling an endless line is
/// answered with the oversized error as soon as the cap is crossed —
/// *before* any newline arrives — instead of growing the server's
/// buffer until memory runs out; the session then recovers once the
/// line finally terminates.
#[test]
fn slow_loris_write_is_answered_before_its_newline() {
    let state = ServeState::new(8);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        let state_ref = &state;
        let server = scope.spawn(move || serve_tcp(state_ref, listener, 2, 4));

        let mut stream = TcpStream::connect(&addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let chunk = vec![b'x'; 600_000];
        stream.write_all(&chunk).unwrap(); // under the 1 MiB cap: no response yet
        std::thread::sleep(Duration::from_millis(300)); // the loris stalls…
        stream.write_all(&chunk).unwrap(); // …then crosses the cap, newline still unsent
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_err(&resp);
        assert!(
            resp.get("error").unwrap().as_str().unwrap().contains("exceeds"),
            "the trickled line must be rejected for size: {resp}"
        );
        // terminating the swallowed line restores normal service
        stream.write_all(b"\n{\"op\":\"ping\"}\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_ok(&Json::parse(line.trim()).unwrap());
        drop(reader);
        drop(stream);

        let bye = client_send(&addr, r#"{"op":"shutdown"}"#).unwrap();
        assert_ok(&Json::parse(&bye).unwrap());
        server.join().unwrap().unwrap();
    });
}

/// A client that fires a solve and vanishes without reading must not
/// leak the worker: with a single-worker pool, a fresh client is served
/// immediately afterwards.
#[test]
fn mid_solve_client_disconnect_does_not_leak_the_worker() {
    let state = ServeState::new(8);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        let state_ref = &state;
        let server = scope.spawn(move || serve_tcp(state_ref, listener, 1, 4));

        {
            let mut rude = TcpStream::connect(&addr).unwrap();
            rude.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            writeln!(rude, "{REGISTER}").unwrap();
            let mut reader = BufReader::new(rude.try_clone().unwrap());
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert_ok(&Json::parse(line.trim()).unwrap());
            // fire the solve and hang up without reading the response
            writeln!(
                rude,
                r#"{{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05}}"#
            )
            .unwrap();
            rude.flush().unwrap();
        } // both halves dropped here: the peer is gone mid-solve

        // the lone worker must finish the orphaned session and take this one
        let responses = client_send_many(
            &addr,
            &[REGISTER.to_string(), r#"{"op":"ping"}"#.to_string()],
        )
        .unwrap();
        assert_eq!(responses.len(), 2, "the worker must survive the disconnect");
        for r in &responses {
            assert_ok(&Json::parse(r).unwrap());
        }

        let bye = client_send(&addr, r#"{"op":"shutdown"}"#).unwrap();
        assert_ok(&Json::parse(&bye).unwrap());
        server.join().unwrap().unwrap();
    });
}

/// A shutdown that lands while a solve is in flight: the solve's stop
/// callback sees the flag, abandons generation after the in-progress
/// round, and still returns a well-formed best-so-far response
/// (`timed_out` set, objective present) instead of panicking or
/// hanging. Requesting shutdown *first* makes the race deterministic:
/// the very first poll sees the flag.
#[test]
fn shutdown_during_solve_returns_best_so_far() {
    let state = ServeState::new(8);
    assert_ok(&Json::parse(&state.handle_line(REGISTER)).unwrap());
    assert_ok(&Json::parse(&state.handle_line(r#"{"op":"shutdown"}"#)).unwrap());
    let resp = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"cache":false}"#,
    ))
    .unwrap();
    assert_ok(&resp);
    assert!(get_bool(&resp, "timed_out"), "the flag must stop generation: {resp}");
    assert!(!get_bool(&resp, "converged"));
    assert_eq!(get_usize(&resp, "rounds"), 1, "exactly the in-progress round completes");
    assert!(get_f64(&resp, "objective").is_finite(), "best-so-far is still a solution");
}

/// Admission control: a saturated server (here: zero solve slots, the
/// drain configuration) rejects solve-class requests with the typed
/// busy response and its `retry_after` backoff hint, while lightweight
/// ops — register, ping, stats — are never gated.
#[test]
fn admission_control_rejects_solves_when_saturated() {
    let state = ServeState::new(8).with_max_inflight(0);
    assert_ok(&Json::parse(&state.handle_line(REGISTER)).unwrap());
    assert_ok(&Json::parse(&state.handle_line(r#"{"op":"ping"}"#)).unwrap());
    assert_ok(&Json::parse(&state.handle_line(r#"{"op":"stats"}"#)).unwrap());
    for gated in [
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05}"#,
        r#"{"op":"grid","dataset":"d","workload":"l1svm","grid":3}"#,
        r#"{"op":"batch","dataset":"d","requests":[{"workload":"l1svm"}]}"#,
    ] {
        let resp = Json::parse(&state.handle_line(gated)).unwrap();
        assert_err(&resp);
        assert_eq!(
            get_usize(&resp, "retry_after"),
            cutgen::serve::RETRY_AFTER_MS,
            "rejections must carry the backoff hint: {resp}"
        );
    }
    // a server with slots admits the same request
    let open = ServeState::new(8).with_max_inflight(2);
    assert_ok(&Json::parse(&open.handle_line(REGISTER)).unwrap());
    assert_ok(&Json::parse(&open.handle_line(
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05}"#,
    ))
    .unwrap());
}

/// Corrupted snapshot spills degrade to cold solves: a restarted server
/// whose persist dir was vandalized serves the request correctly
/// (cold, converged) instead of panicking or reporting a bogus warm
/// start.
#[test]
fn corrupt_persist_files_degrade_to_cold_solves() {
    let dir =
        std::env::temp_dir().join(format!("cutgen-persist-faults-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let solve =
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"eps":1e-6}"#;
    let first = ServeState::new(8).with_persist_dir(&dir).unwrap();
    assert_ok(&Json::parse(&first.handle_line(REGISTER)).unwrap());
    let cold = Json::parse(&first.handle_line(solve)).unwrap();
    assert_ok(&cold);
    drop(first);
    // vandalize every spilled snapshot
    let mut clobbered = 0usize;
    for f in std::fs::read_dir(&dir).unwrap() {
        let path = f.unwrap().path();
        std::fs::write(&path, b"{not json").unwrap();
        clobbered += 1;
    }
    assert!(clobbered >= 1, "the first life must have spilled a snapshot");
    let second = ServeState::new(8).with_persist_dir(&dir).unwrap();
    assert_ok(&Json::parse(&second.handle_line(REGISTER)).unwrap());
    let resp = Json::parse(&second.handle_line(solve)).unwrap();
    assert_ok(&resp);
    assert!(!get_bool(&resp, "warm"), "corrupt spills must read as misses: {resp}");
    assert!(get_bool(&resp, "converged"));
    let reference = get_f64(&cold, "objective");
    let after = get_f64(&resp, "objective");
    assert!(
        (after - reference).abs() / reference.max(1e-9) <= 1e-6,
        "the cold re-solve must match the original: {after} vs {reference}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The serve-level deadline contract. A deadline too tight to converge
/// still returns `ok` with a feasible best-so-far answer: `timed_out`
/// is reported honestly, and the restricted objective can only sit at
/// or above the fully converged one (column generation improves the
/// objective monotonically as columns enter).
#[test]
fn deadline_capped_solve_returns_feasible_best_so_far() {
    let state = ServeState::new(8);
    assert_ok(&Json::parse(&state.handle_line(
        r#"{"op":"register","name":"big","synthetic":{"kind":"l1","n":100,"p":400,"seed":29}}"#,
    ))
    .unwrap());
    let full = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"big","workload":"l1svm","lambda_frac":0.02,"eps":1e-8,"max_cols_per_round":1,"cache":false}"#,
    ))
    .unwrap();
    assert_ok(&full);
    assert!(get_bool(&full, "converged"));
    assert!(!get_bool(&full, "timed_out"));
    let capped = Json::parse(&state.handle_line(
        r#"{"op":"solve","dataset":"big","workload":"l1svm","lambda_frac":0.02,"eps":1e-8,"max_cols_per_round":1,"cache":false,"deadline_ms":1}"#,
    ))
    .unwrap();
    assert_ok(&capped);
    assert!(
        get_bool(&capped, "converged") || get_bool(&capped, "timed_out"),
        "a capped solve either finishes or says it was cut: {capped}"
    );
    let full_obj = get_f64(&full, "objective");
    let capped_obj = get_f64(&capped, "objective");
    assert!(capped_obj.is_finite(), "best-so-far must be a real solution");
    assert!(
        capped_obj >= full_obj * (1.0 - 1e-9),
        "a restricted objective cannot beat the converged one: {capped_obj} vs {full_obj}"
    );
    if get_bool(&capped, "timed_out") {
        assert!(
            get_usize(&capped, "rounds") <= get_usize(&full, "rounds"),
            "a cut solve cannot run longer than the full one"
        );
    }
}

/// A generous deadline is observationally free: with the cache pinned
/// off, the response is **byte-identical** to the same request with no
/// deadline at all — `timed_out:false` is always present, so the field
/// layout does not depend on whether a deadline was supplied.
#[test]
fn generous_deadline_is_byte_identical_to_none() {
    let state = ServeState::new(8);
    assert_ok(&Json::parse(&state.handle_line(REGISTER)).unwrap());
    let bare = state.handle_line(
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"cache":false}"#,
    );
    let generous = state.handle_line(
        r#"{"op":"solve","dataset":"d","workload":"l1svm","lambda_frac":0.05,"cache":false,"deadline_ms":600000}"#,
    );
    assert_ok(&Json::parse(&bare).unwrap());
    assert_eq!(bare, generous, "a generous deadline must not perturb the response");
}

/// Batch-level faults: non-object items and unknown workloads fail
/// inline without poisoning their neighbors, and the session keeps
/// serving afterwards.
#[test]
fn broken_batch_items_fail_inline_only() {
    let state = ServeState::new(8);
    assert_ok(&Json::parse(&state.handle_line(REGISTER)).unwrap());
    let resp = Json::parse(&state.handle_line(concat!(
        r#"{"op":"batch","dataset":"d","requests":["#,
        r#"42,"#,
        r#"{"workload":"lasso"},"#,
        r#"{"workload":"l1svm","lambda_frac":0.05}"#,
        r#"]}"#,
    )))
    .unwrap();
    assert_ok(&resp);
    assert_eq!(get_usize(&resp, "count"), 3);
    let results = resp.get("results").unwrap().as_arr().unwrap();
    assert_err(&results[0]);
    assert_err(&results[1]);
    assert_ok(&results[2]);
    assert_ok(&Json::parse(&state.handle_line(r#"{"op":"ping"}"#)).unwrap());
}

/// TCP handshake under a full accept queue: with a saturated bounded
/// queue the acceptor itself answers the busy response and closes —
/// load shedding is visible to the client rather than an invisible,
/// unbounded backlog. (`drain` keeps a worker pinned so queued
/// connections stay queued.)
#[test]
fn full_accept_queue_sheds_load_with_the_busy_response() {
    let state = ServeState::new(8);
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        let state_ref = &state;
        let server = scope.spawn(move || serve_tcp(state_ref, listener, 1, 1));

        // pin the only worker with an open, idle session
        let pin = TcpStream::connect(&addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        // fill the queue with a second idle connection
        let queued = TcpStream::connect(&addr).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        // the third must be shed by the acceptor with a busy line
        let mut shed = TcpStream::connect(&addr).unwrap();
        shed.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut line = String::new();
        let n = BufReader::new(shed.try_clone().unwrap()).read_line(&mut line).unwrap();
        assert!(n > 0, "the shed connection must get the busy line before close");
        let resp = Json::parse(line.trim()).unwrap();
        assert_err(&resp);
        assert_eq!(get_usize(&resp, "retry_after"), cutgen::serve::RETRY_AFTER_MS);
        // …and nothing more: the acceptor hung up
        let mut rest = Vec::new();
        let _ = shed.read_to_end(&mut rest);
        assert!(rest.is_empty(), "shed connections are closed after the busy line");
        drop(shed);
        drop(pin); // frees the worker, which then drains `queued`
        drop(queued);

        let bye = client_send(&addr, r#"{"op":"shutdown"}"#).unwrap();
        assert_ok(&Json::parse(&bye).unwrap());
        server.join().unwrap().unwrap();
    });
}
